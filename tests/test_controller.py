"""Online multi-tenant controller (DESIGN.md §13): incremental admission,
value-based preemption, weighted max-min fairness, and re-expansion.

The controller's contract with the rest of the repo: admissions run the
placement search ONLY on the residual capability (no full replan),
preempted victims degrade through ``planner.repair_placement`` — the same
path switch crashes take, so in-flight state stays exactly-once via the
§12 epoch-restart driver — and departures re-expand degraded survivors
only when the re-search actually buys scarce-uplink bytes.  ``plan()``
is the one planning front door; its routing table is pinned here.
"""

import numpy as np
import pytest

from repro.core import controller as ctl_lib
from repro.core import planner as pl
from repro.core import plan
from repro.core.controller import (Admission, OnlineController,
                                   OnlineJobRequest, weighted_max_min)


def _ft(**kw):
    base = dict(pods=2, tors_per_pod=2, hosts_per_tor=2,
                oversubscription=2.0, table_pairs=256)
    base.update(kw)
    return pl.FatTreeTopology(**base)


def _req(jid, *, pairs=512, variety=128, tenant="a", value=1.0):
    return OnlineJobRequest(job_id=jid, expected_pairs=pairs,
                            key_variety=variety, tenant=tenant, value=value)


# ---------------------------------------------------------------------------
# Admission + residual accounting.
# ---------------------------------------------------------------------------


def test_first_admission_gets_full_capability():
    ctl = OnlineController(_ft())
    adm = ctl.admit(_req(0, variety=128))
    assert isinstance(adm, Admission)
    assert not adm.degraded and adm.preempted == ()
    # capability = min(variety, table) on every placeable tier
    assert dict(adm.caps) == {t: 128 for t in ctl.placeable_tiers()}
    # reservations only on tiers the placement actually uses
    for tier, pairs in adm.grants:
        assert tier in adm.placement.tiers and pairs == 128
        assert ctl.used_pairs(tier) == 128
        assert ctl.residual_pairs(tier) == 256 - 128


def test_admission_is_incremental_not_a_replan():
    """Admitting job k never re-places jobs 0..k-1."""
    ctl = OnlineController(_ft())
    placements = {}
    for j in range(3):
        ctl.admit(_req(j, variety=64))
        placements[j] = {i: ctl.jobs[i].placement for i in ctl.jobs}
    # earlier jobs' placements are the very same objects at every step
    assert placements[2][0] is placements[0][0]
    assert placements[2][1] is placements[1][1]


def test_exhausted_tier_degrades_lower_value_arrivals():
    """With preemption off, an arrival on a full fabric degrades (fewer
    tiers / host-only) instead of failing."""
    ctl = OnlineController(_ft(table_pairs=128), preemption=False)
    first = ctl.admit(_req(0, variety=128))
    adm = ctl.admit(_req(1, variety=128))
    assert adm.degraded and adm.preempted == ()
    # tiers the first job reserved are off-limits; only leftovers granted
    taken = dict(first.grants)
    assert all(t not in taken for t, _ in adm.grants)
    # a degraded job still has a legal placement
    assert adm.placement.scarce_uplink_bytes > 0


def test_duplicate_job_id_rejected():
    ctl = OnlineController(_ft())
    ctl.admit(_req(0))
    with pytest.raises(ValueError, match="already active"):
        ctl.admit(_req(0))


def test_request_validation():
    with pytest.raises(ValueError):
        OnlineJobRequest(job_id=0, expected_pairs=0, key_variety=8)
    with pytest.raises(ValueError):
        OnlineJobRequest(job_id=0, expected_pairs=8, key_variety=0)
    with pytest.raises(ValueError):
        OnlineJobRequest(job_id=0, expected_pairs=8, key_variety=8,
                         value=-1.0)


# ---------------------------------------------------------------------------
# Value-based preemption -> repair_placement -> exactly-once.
# ---------------------------------------------------------------------------


def test_high_value_arrival_preempts_low_value_victim():
    ctl = OnlineController(_ft(table_pairs=128))
    ctl.admit(_req(0, variety=128, value=1.0))
    before = ctl.jobs[0].placement
    adm = ctl.admit(_req(1, variety=128, value=5.0))
    assert adm.preempted == (0,)
    assert not adm.degraded  # preemption bought full capability
    assert ctl.evictions  # recorded, with before/after placements
    ev = ctl.evictions[0]
    assert ev.job_id == 0 and ev.by_job == 1
    assert ev.before is before
    # the victim was repaired, not killed: still active, now degraded
    assert 0 in ctl.jobs and ctl.jobs[0].degraded
    assert ctl.jobs[0].grants.get(ev.tier, 0) == 0
    # repair went through planner.repair_placement
    assert ctl.jobs[0].placement.policy.startswith("repair(")


def test_low_value_arrival_never_preempts():
    ctl = OnlineController(_ft(table_pairs=128))
    ctl.admit(_req(0, variety=128, value=5.0))
    adm = ctl.admit(_req(1, variety=128, value=1.0))
    assert adm.preempted == () and adm.degraded
    assert not ctl.evictions


def test_partial_residual_degrades_instead_of_evicting():
    """Preemption only fires when a tier is EXHAUSTED; any residual
    table means the arrival takes the partial grant."""
    ctl = OnlineController(_ft(table_pairs=192))
    first = ctl.admit(_req(0, variety=128, value=1.0))  # leaves 64/tier
    adm = ctl.admit(_req(1, variety=128, value=9.0))
    assert adm.preempted == () and not ctl.evictions
    # on contended tiers the arrival takes the 64-pair residual, degraded
    taken = dict(first.grants)
    caps = dict(adm.caps)
    assert all(caps[t] == 64 for t in taken)
    assert adm.degraded


def test_eviction_failure_events_drive_exactly_once_recovery():
    """The eviction's FailureEvents ride the §12 epoch-restart driver: a
    victim mid-job delivers the same table as its clean run."""
    from repro.net import simulate
    from repro.net.sim import NetConfig
    from repro.runtime.fault_tolerance import FailureInjector

    ft = _ft(table_pairs=64)
    ctl = OnlineController(ft)
    victim = ctl.admit(_req(0, pairs=64, variety=64, value=1.0))
    ctl.admit(_req(1, pairs=64, variety=64, value=5.0))
    assert ctl.evictions
    ev = ctl.evictions[0]
    events = ctl.eviction_failure_events(ev, t_s=1e-5)
    # one switch_crash per switch of the evicted tier
    lvl = ctl._tier_level(ev.tier)
    fanins = tuple(l.fanin for l in ft.link_tiers())
    assert len(events) == int(np.prod(fanins[lvl + 1:]))
    assert all(e.kind == "switch_crash" and e.level == lvl for e in events)

    rng = np.random.default_rng(0)
    n = ft.n_hosts * 64
    keys = rng.integers(0, 64, size=n).astype(np.int32)
    vals = rng.integers(1, 5, size=n).astype(np.float64)
    clean = simulate(ft, keys, vals, placement=victim.placement,
                     cfg=NetConfig(seed=3))
    faulted = simulate(
        ft, keys, vals, placement=victim.placement,
        faults=FailureInjector({}, events=events),
        cfg=NetConfig(seed=3, loss_rate=0.05))
    assert faulted.delivered_table() == clean.delivered_table()
    assert faulted.epochs > 1


# ---------------------------------------------------------------------------
# Weighted max-min fairness.
# ---------------------------------------------------------------------------


def test_weighted_max_min_water_filling():
    shares = weighted_max_min({"a": 10.0, "b": 100.0, "c": 100.0},
                              {"a": 1.0, "b": 2.0, "c": 1.0}, 100.0)
    # a fits under its share and keeps its demand; the surplus water-fills
    # b:c at 2:1
    assert shares["a"] == pytest.approx(10.0)
    assert shares["b"] == pytest.approx(60.0)
    assert shares["c"] == pytest.approx(30.0)
    assert sum(shares.values()) == pytest.approx(100.0)
    # no contention: everyone keeps their demand
    easy = weighted_max_min({"a": 5.0, "b": 5.0}, {}, 100.0)
    assert easy == {"a": 5.0, "b": 5.0}


def test_fair_shares_follow_tenant_weights():
    ctl = OnlineController(_ft(), tenant_weights={"a": 2.0, "b": 1.0},
                           scarce_budget_bytes=1.0)
    ctl.admit(_req(0, tenant="a"))
    ctl.admit(_req(1, tenant="b"))
    shares = ctl.fair_shares()
    # both saturate an (artificially) scarce budget: split 2:1
    assert shares["a"] / shares["b"] == pytest.approx(2.0)
    rep = ctl.report()
    assert rep.tenants["a"]["weight"] == 2.0
    assert rep.tenants["a"]["n_jobs"] == 1


# ---------------------------------------------------------------------------
# Departure -> re-expansion.
# ---------------------------------------------------------------------------


def test_release_reexpands_degraded_survivor():
    ctl = OnlineController(_ft(table_pairs=128), preemption=False)
    ctl.admit(_req(0, variety=128))
    degraded = ctl.admit(_req(1, variety=128))
    assert degraded.degraded
    before_bytes = ctl.jobs[1].placement.scarce_uplink_bytes
    expansions = ctl.release(0)
    assert 0 not in ctl.jobs
    assert [e.job_id for e in expansions] == [1]
    assert not ctl.jobs[1].degraded
    assert expansions[0].scarce_bytes_saved > 0
    assert ctl.jobs[1].placement.scarce_uplink_bytes < before_bytes
    # grants now cover the freed capability
    assert ctl.jobs[1].grants
    assert ctl.expansions == expansions


def test_release_is_idempotent():
    ctl = OnlineController(_ft())
    assert ctl.release(99) == []  # unknown/already-departed: a no-op
    ctl.admit(_req(0))
    ctl.release(0)
    assert ctl.release(0) == [] and not ctl.jobs


def test_report_snapshot_counts():
    ctl = OnlineController(_ft(table_pairs=128), preemption=False)
    ctl.admit(_req(0, variety=128))
    ctl.admit(_req(1, variety=128))
    rep = ctl.report()
    assert rep.n_active == 2 and rep.n_degraded == 1
    assert rep.admitted_total == 2
    assert rep.scarce_axis == ctl.ft.scarce_uplink_axis()
    assert rep.total_scarce_bytes == pytest.approx(ctl.total_scarce_bytes())
    d = rep.to_dict()
    assert d["n_active"] == 2 and "scarce_utilization" in d
    assert "admitted" in rep.summary()


# ---------------------------------------------------------------------------
# plan(): the one planning front door.
# ---------------------------------------------------------------------------


def test_plan_routes_online_requests_to_a_controller():
    ft = _ft()
    adm = plan(_req(0), ft)
    assert isinstance(adm, Admission)
    got = plan([_req(1), _req(2, tenant="b")], ft,
               tenant_weights={"a": 2.0, "b": 1.0})
    assert isinstance(got, OnlineController)
    assert sorted(got.jobs) == [1, 2]
    assert got.tenant_weights == {"a": 2.0, "b": 1.0}
    # live-instance routing: incremental admission on the same controller
    adm3 = plan(_req(3), got)
    assert adm3.job_id == 3 and 3 in got.jobs


def test_plan_routes_launch_requests():
    ft = _ft()
    lr = pl.LaunchRequest(job_id=1, n_workers=ft.n_hosts,
                          expected_pairs=64, key_variety=64)
    jp = plan(lr, ft)
    assert hasattr(jp, "configure") and hasattr(jp, "tree")  # a JobPlan

    topo = ft.to_topology()
    jp2 = plan(lr, topo, combiner_budget_pairs=256)
    assert hasattr(jp2, "configure")
    reqs = [pl.LaunchRequest(job_id=j + 1, n_workers=8, expected_pairs=64,
                             key_variety=64) for j in range(2)]
    rep = plan(reqs, topo, combiner_budget_pairs=256)
    assert len(list(rep.jobs)) == 2  # a SchedulerReport

    sched = pl.JobScheduler(topo, combiner_budget_pairs=256)
    jp3 = plan(pl.LaunchRequest(job_id=9, n_workers=8, expected_pairs=64,
                                key_variety=64), sched)
    assert jp3.configure.tree_id == 9


def test_plan_rejects_unroutable_shapes():
    with pytest.raises(TypeError, match="cannot dispatch"):
        plan(_req(0), "not a topology")
    with pytest.raises(TypeError, match="OnlineJobRequest"):
        plan([_req(0), pl.LaunchRequest(job_id=1, n_workers=2,
                                        expected_pairs=8, key_variety=8)],
             _ft())


def test_controller_metrics_published():
    from repro.obs import metrics as obs_metrics

    reg = obs_metrics.get_registry()
    ctl = OnlineController(_ft(table_pairs=128))
    ctl.admit(_req(0, variety=128, value=1.0, tenant="a"))
    ctl.admit(_req(1, variety=128, value=5.0, tenant="b"))
    assert reg.value("controller.active_jobs") == 2
    assert reg.value("controller.admitted_total", tenant="a") >= 1
    assert sum(v for _, v in reg.find("controller.evictions_total")) >= 1
    assert ctl.candidates_scored_total > 0
