"""Compressed (top-k KV + FPE/BPE) gradient exchange, end to end.

Checks: (a) k_fraction=1 + no-FPE == exact TREE numerics; (b) with real
compression (k=5%) + bounded-memory node training still converges and the
error-feedback residuals stay bounded. 8 fake CPU devices.
"""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced_config
from repro.core.collectives import GradAggMode
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import LMModel
from repro.optim import AdamWConfig, adamw_init, make_lr_schedule
from repro.train.compressed import build_compressed_train_step
from repro.train.step import TrainProfile, build_train_step

assert jax.device_count() == 8

CFG = dataclasses.replace(reduced_config("phi4-mini-3.8b"), dtype="float32")
DATA = SyntheticLMData(CFG, DataConfig(seq_len=16, global_batch=8, seed=0))
OPT = AdamWConfig(master_fp32=False)
LR = make_lr_schedule(1e-3, 2, 100)
MESH = jax.make_mesh((2, 2, 2), ("data", "pod", "model"))
PROF = TrainProfile(dp_axes=("data", "pod"), tp_axis="model",
                    q_chunk=16, k_chunk=16, moe_token_chunk=16,
                    remat="none", mode=GradAggMode.TREE_COMPRESS)


def build_compressed(k_fraction, fpe_capacity):
    model = LMModel(CFG)
    params = model.init(jax.random.PRNGKey(0))
    step_fn, sh = build_compressed_train_step(
        CFG, MESH, PROF, OPT, LR,
        batch_example=DATA.batch_at(0), params_example=params,
        k_fraction=k_fraction, fpe_capacity=fpe_capacity,
    )
    params = jax.device_put(params, sh["params"])
    opt = jax.jit(lambda p: adamw_init(p, OPT), out_shardings=sh["opt"])(params)
    res = jax.device_put(sh["res_example"], sh["residuals"])
    return step_fn, params, opt, res


def build_exact_tree():
    prof = dataclasses.replace(PROF, mode=GradAggMode.TREE)
    model = LMModel(CFG)
    params = model.init(jax.random.PRNGKey(0))
    step_fn, sh, _ = build_train_step(
        CFG, MESH, prof, OPT, LR,
        batch_example=DATA.batch_at(0), params_example=params)
    params = jax.device_put(params, sh["params"])
    opt = jax.jit(lambda p: adamw_init(p, OPT), out_shardings=sh["opt"])(params)
    return step_fn, params, opt


def check_lossless_limit():
    """k = 100% of each shard and no FPE cap: exchange must be exact."""
    step_c, p_c, o_c, r_c = build_compressed(k_fraction=1.0, fpe_capacity=0)
    step_t, p_t, o_t = build_exact_tree()
    for i in range(3):
        b = DATA.batch_at(i)
        si = jnp.asarray(i, jnp.int32)
        p_c, o_c, r_c, m_c = step_c(p_c, o_c, r_c, b, si)
        p_t, o_t, m_t = step_t(p_t, o_t, b, si)
        assert abs(float(m_c["loss"]) - float(m_t["loss"])) < 2e-4, (
            i, float(m_c["loss"]), float(m_t["loss"]))
    for a, b_ in zip(jax.tree.leaves(jax.tree.map(np.asarray, p_c)),
                     jax.tree.leaves(jax.tree.map(np.asarray, p_t))):
        np.testing.assert_allclose(a, b_, atol=3e-4, rtol=1e-3)
    # nothing withheld when k is full
    assert max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(r_c)) < 1e-5
    print("lossless limit OK")


def check_real_compression_converges():
    step_c, p, o, r = build_compressed(k_fraction=0.05, fpe_capacity=64)
    losses = []
    res_norm = []
    for i in range(8):
        b = DATA.batch_at(i % 2)  # small cycling set -> clear loss decrease
        p, o, r, m = step_c(p, o, r, b, jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
        res_norm.append(max(float(jnp.linalg.norm(l)) for l in jax.tree.leaves(r)))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
    assert res_norm[-1] < 10 * (res_norm[0] + 1e-3), res_norm  # bounded EF
    print(f"compressed training converges OK: {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    check_lossless_limit()
    check_real_compression_converges()
    print("ALL OK")
