"""GPipe pipeline schedule: correctness vs sequential execution. 8 devices."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.pipeline import bubble_fraction, make_gpipe_fn

assert jax.device_count() == 8


def check_pipeline_matches_sequential():
    n_stages, m, mb, d = 8, 16, 4, 32
    mesh = jax.make_mesh((8,), ("stage",))
    rng = np.random.default_rng(0)
    # per-stage params: one linear + nonlinearity per stage
    w = jnp.asarray(rng.standard_normal((n_stages, d, d)).astype(np.float32) * 0.2)
    x = jnp.asarray(rng.standard_normal((m, mb, d)).astype(np.float32))

    def stage_fn(wi, xi):
        return jnp.tanh(xi @ wi)

    fn = make_gpipe_fn(mesh, "stage", n_stages, stage_fn)
    got = np.asarray(fn(w, x))

    want = np.asarray(x)
    for s in range(n_stages):
        want = np.tanh(want @ np.asarray(w[s]))
    np.testing.assert_allclose(got, want, atol=1e-5)
    print(f"pipeline == sequential OK (stages={n_stages}, micro={m}, "
          f"bubble={bubble_fraction(m, n_stages):.2f})")


def check_bubble_math():
    assert abs(bubble_fraction(16, 8) - 7 / 23) < 1e-12
    assert bubble_fraction(1000, 8) < 0.01  # M >> S amortizes the bubble
    print("bubble math OK")


if __name__ == "__main__":
    check_pipeline_matches_sequential()
    check_bubble_math()
    print("ALL OK")
