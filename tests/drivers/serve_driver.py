"""Multi-device serving: TP + model-axis-sharded KV cache (flash-decode)
must reproduce the single-device decode exactly. 8 fake CPU devices."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import LMModel
from repro.models import transformer as tfm
from repro.train.step import TrainProfile, build_prefill_step, build_serve_step

assert jax.device_count() == 8


def _oracle_decode(cfg, params, batch, n_pre, n_dec, cache_len):
    """Plain single-jit prefill+decode (no mesh)."""
    model = LMModel(cfg, opt=tfm.ApplyOptions(q_chunk=8, k_chunk=8, remat="none"))
    pre = {k: (v[:, :n_pre] if k in ("tokens", "frame_embeds") else v)
           for k, v in batch.items() if k != "labels"}
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, cache_len))(params, pre)
    toks = [np.asarray(jnp.argmax(logits[:, -1], -1))]
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    cur = jnp.asarray(toks[-1][:, None], jnp.int32)
    for i in range(n_dec):
        lg, caches = step(params, cur, caches, jnp.asarray(cfg.prefix_tokens + n_pre + i, jnp.int32))
        cur = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(np.asarray(cur[:, 0]))
    return np.stack(toks, 1)  # [B, 1+n_dec]


def check_sharded_decode(arch, batch_size, batch_shardable):
    cfg = dataclasses.replace(reduced_config(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    prof = TrainProfile(dp_axes=("data",), tp_axis="model",
                        q_chunk=8, k_chunk=8, moe_token_chunk=64, remat="none")
    n_pre, n_dec, cache_len = 8, 5, 32
    data = SyntheticLMData(cfg, DataConfig(seq_len=16, global_batch=batch_size, seed=1))
    batch = data.batch_at(0)
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    want = _oracle_decode(cfg, params, batch, n_pre, n_dec,
                          cache_len + cfg.prefix_tokens)

    # distributed: prefill then serve steps with model-axis-sharded caches
    pre_batch = {k: (v[:, :n_pre] if k in ("tokens", "frame_embeds") else v)
                 for k, v in batch.items() if k != "labels"}
    prefill_fn, sh_p, _ = build_prefill_step(
        cfg, mesh, prof, cache_len=cache_len + cfg.prefix_tokens,
        batch_example=pre_batch, params_example=params,
        batch_shardable=batch_shardable, cache_seq_axes=("model",),
    )
    serve_fn, sh_s, _ = build_serve_step(
        cfg, mesh, prof, cache_len=cache_len + cfg.prefix_tokens,
        batch=batch_size, params_example=params,
        batch_shardable=batch_shardable, cache_seq_axes=("model",),
    )
    logits, caches = prefill_fn(params, pre_batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    got = [np.asarray(tok[:, 0])]
    for i in range(n_dec):
        tok, caches = serve_fn(params, caches, tok,
                               jnp.asarray(cfg.prefix_tokens + n_pre + i, jnp.int32))
        got.append(np.asarray(tok[:, 0]))
    got = np.stack(got, 1)
    np.testing.assert_array_equal(got, want)
    print(f"sharded decode OK: {arch} batch={batch_size} "
          f"shardable={batch_shardable} tokens={got[0].tolist()}")


if __name__ == "__main__":
    check_sharded_decode("gemma2-27b", 4, True)     # GQA + local/global + softcap
    check_sharded_decode("olmoe-1b-7b", 1, False)   # MoE, unshardable batch=1
    check_sharded_decode("deepseek-v2-236b", 4, True)  # MLA latent cache
    print("ALL OK")
