"""End-to-end multi-device training: flat == tree == gather numerics,
checkpoint/restart mid-run, elastic re-mesh. 8 fake CPU devices."""

import os
import tempfile

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.reduced import reduced_config
from repro.core.collectives import GradAggMode
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import LMModel
from repro.optim import AdamWConfig, adamw_init, make_lr_schedule
from repro.train.step import TrainProfile, build_train_step

assert jax.device_count() == 8

CFG = dataclasses.replace(
    reduced_config("olmoe-1b-7b"), dtype="float32")  # MoE: exercises EP a2a
DATA = SyntheticLMData(CFG, DataConfig(seq_len=16, global_batch=8, seed=0))
OPT = AdamWConfig(master_fp32=True)
LR = make_lr_schedule(1e-3, 2, 100)


def build(mesh, mode):
    prof = TrainProfile(
        dp_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
        tp_axis="model", q_chunk=16, k_chunk=16, moe_token_chunk=16,
        remat="none", mode=mode,
    )
    model = LMModel(CFG)
    params = model.init(jax.random.PRNGKey(0))
    step_fn, shardings, _ = build_train_step(
        CFG, mesh, prof, OPT, LR,
        batch_example=DATA.batch_at(0), params_example=params,
    )
    params = jax.device_put(params, shardings["params"])
    opt = jax.jit(lambda p: adamw_init(p, OPT),
                  out_shardings=shardings["opt"])(params)
    return step_fn, params, opt, shardings


def run_steps(step_fn, params, opt, start, n):
    losses = []
    for i in range(start, start + n):
        params, opt, m = step_fn(params, opt, DATA.batch_at(i),
                                 jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    return params, opt, losses


def check_modes_agree():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    results = {}
    for mode in (GradAggMode.FLAT, GradAggMode.TREE, GradAggMode.GATHER):
        step_fn, params, opt, _ = build(mesh, mode)
        params, opt, losses = run_steps(step_fn, params, opt, 0, 4)
        results[mode] = (jax.tree.map(np.asarray, params), losses)
        assert all(np.isfinite(l) for l in losses), (mode, losses)
    ref_p, ref_l = results[GradAggMode.FLAT]
    for mode in (GradAggMode.TREE, GradAggMode.GATHER):
        p, l = results[mode]
        np.testing.assert_allclose(l, ref_l, rtol=2e-4,
                                   err_msg=f"{mode} losses differ")
        for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(p)):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)
    # training makes progress
    assert ref_l[-1] < ref_l[0], ref_l
    print(f"modes agree OK: losses {ref_l}")


def check_checkpoint_restart_and_elastic():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    step_fn, params, opt, shardings = build(mesh, GradAggMode.TREE)
    params, opt, l1 = run_steps(step_fn, params, opt, 0, 3)
    ckdir = tempfile.mkdtemp(prefix="ckpt_")
    mgr = CheckpointManager(ckdir, keep=2)
    mgr.save(2, {"params": params, "opt": opt})
    # continue the original
    params_a, opt_a, la = run_steps(step_fn, params, opt, 3, 3)

    # 'failure': rebuild from checkpoint on the SAME mesh
    step_fn2, params0, opt0, sh2 = build(mesh, GradAggMode.TREE)
    restored, manifest = mgr.restore({"params": params0, "opt": opt0})
    params_b = jax.device_put(restored["params"], sh2["params"])
    opt_b = jax.device_put(restored["opt"], sh2["opt"])
    params_b, opt_b, lb = run_steps(step_fn2, params_b, opt_b, 3, 3)
    np.testing.assert_allclose(la, lb, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, params_a)),
                    jax.tree.leaves(jax.tree.map(np.asarray, params_b))):
        np.testing.assert_allclose(a, b, atol=1e-6)
    print(f"checkpoint restart OK: losses {lb}")

    # ELASTIC: restart the same checkpoint on a DIFFERENT mesh (no pod axis,
    # 4-wide data) — checkpoints are mesh-agnostic full arrays.
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    step_fn3, params0, opt0, sh3 = build(mesh2, GradAggMode.TREE)
    restored2, _ = mgr.restore({"params": params0, "opt": opt0})
    params_c = jax.device_put(restored2["params"], sh3["params"])
    opt_c = jax.device_put(restored2["opt"], sh3["opt"])
    params_c, opt_c, lc = run_steps(step_fn3, params_c, opt_c, 3, 3)
    np.testing.assert_allclose(lc, la, rtol=2e-4)  # same numerics on new mesh
    print(f"elastic re-mesh OK: losses {lc}")


if __name__ == "__main__":
    check_modes_agree()
    check_checkpoint_restart_and_elastic()
    print("ALL OK")
