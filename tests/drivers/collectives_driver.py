"""Multi-device (8 fake CPU) checks of the SwitchAgg collective dataplane.

Run by tests/test_multidevice.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""), \
    "driver must run with fake devices"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as coll
from repro.core.collectives import shard_map_compat
from repro.core import kvagg

assert jax.device_count() == 8, jax.device_count()

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


def check_tree_equals_flat():
    """tree_allreduce == flat psum over (pod,data), for awkward shapes."""
    rng = np.random.default_rng(0)
    for shape in [(64,), (7, 5), (3, 33)]:  # non-divisible sizes hit padding
        x = jnp.asarray(rng.standard_normal((2, 2, *shape)).astype(np.float32))

        def flat(xl):
            return coll.flat_allreduce(xl, ("data", "pod"))

        def tree(xl):
            return coll.tree_allreduce(xl, "data", ("pod",))

        specs = P("pod", "data")
        run = lambda f: jax.jit(shard_map_compat(
            f, mesh=mesh, in_specs=specs, out_specs=specs,
            axis_names={"pod", "data"}, check_vma=False))(x)
        a, b = run(flat), run(tree)
        # reduce-scatter+psum reassociates the sum: fp noise only
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    print("tree==flat OK")


def check_compressed_exact_when_k_full():
    """k = full shard and no fpe cap -> compression is lossless."""
    rng = np.random.default_rng(1)
    n = 128
    x = jnp.asarray(rng.standard_normal((2, 2, n)).astype(np.float32))
    res0 = jnp.zeros((8, n // 2), jnp.float32).reshape(2, 2, 2, n // 2)

    def cmp_fn(xl, rl):
        out, nr = coll.tree_compress_allreduce(
            xl.reshape(-1), rl.reshape(-1), "data", ("pod",), k=n // 2,
            fpe_capacity=0)
        return out.reshape(xl.shape), nr.reshape(rl.shape)

    def flat(xl):
        return coll.flat_allreduce(xl, ("data", "pod"))

    got, nr = jax.jit(shard_map_compat(
        cmp_fn, mesh=mesh,
        in_specs=(P("pod", "data"), P("pod", "data", "model")),
        out_specs=(P("pod", "data"), P("pod", "data", "model")),
        axis_names={"pod", "data", "model"}, check_vma=False))(x, res0)
    want = jax.jit(shard_map_compat(
        flat, mesh=mesh, in_specs=P("pod", "data"), out_specs=P("pod", "data"),
        axis_names={"pod", "data"}, check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(nr))) < 1e-6  # nothing left behind
    print("compressed(k=full)==flat OK")


def check_compressed_with_fpe_node():
    """With the bounded-memory FPE node on the pod boundary the result is
    still exact: evictions are BPE-combined and duplicates decompress-add."""
    rng = np.random.default_rng(2)
    n = 128
    x = jnp.asarray(rng.standard_normal((2, 2, n)).astype(np.float32))
    res0 = jnp.zeros((2, 2, 2, n // 2), jnp.float32)

    def cmp_fn(xl, rl):
        out, nr = coll.tree_compress_allreduce(
            xl.reshape(-1), rl.reshape(-1), "data", ("pod",), k=n // 2,
            fpe_capacity=16)  # tiny FPE: heavy eviction path
        return out.reshape(xl.shape), nr.reshape(rl.shape)

    got, _ = jax.jit(shard_map_compat(
        cmp_fn, mesh=mesh,
        in_specs=(P("pod", "data"), P("pod", "data", "model")),
        out_specs=(P("pod", "data"), P("pod", "data", "model")),
        axis_names={"pod", "data", "model"}, check_vma=False))(x, res0)

    def flat(xl):
        return coll.flat_allreduce(xl, ("data", "pod"))

    want = jax.jit(shard_map_compat(
        flat, mesh=mesh, in_specs=P("pod", "data"), out_specs=P("pod", "data"),
        axis_names={"pod", "data"}, check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    print("compressed(fpe=16)==flat OK")


def check_kv_tree_wordcount():
    """The word-count dataplane: 8 workers' KV streams -> root aggregate."""
    rng = np.random.default_rng(3)
    n_per, variety = 256, 64
    keys = rng.integers(0, variety, size=8 * n_per).astype(np.int32)
    vals = np.ones(8 * n_per, np.float32)
    agg = coll.make_kv_tree_aggregator(
        mesh, ("data", "pod"), fpe_capacity=32, ways=4, bpe=True)
    kspec = NamedSharding(mesh, P(("data", "pod")))
    res = agg(jax.device_put(jnp.asarray(keys), kspec),
              jax.device_put(jnp.asarray(vals), kspec))
    # conservation at the root
    got = {}
    for k, v in zip(np.asarray(res.keys).tolist(), np.asarray(res.values).tolist()):
        if k != -1:
            got[k] = got.get(k, 0) + v
    want = {}
    for k in keys.tolist():
        want[k] = want.get(k, 0) + 1.0
    assert got.keys() == want.keys()
    for k in want:
        assert abs(got[k] - want[k]) < 1e-4, (k, got[k], want[k])
    li, lo = np.asarray(res.level_in), np.asarray(res.level_out)
    assert li[0] > 0 and (lo <= li).all()  # every hop reduces (or keeps) traffic
    print(f"kv tree OK: level_in={li.tolist()} level_out={lo.tolist()} "
          f"root_reduction={1 - lo[-1] / li[0]:.3f}")


if __name__ == "__main__":
    check_tree_equals_flat()
    check_compressed_exact_when_k_full()
    check_compressed_with_fpe_node()
    check_kv_tree_wordcount()
    print("ALL OK")
