"""Docs contract: DESIGN.md exists and every §N citation in src/ resolves."""

import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_design_refs  # noqa: E402


def test_design_md_exists():
    assert (REPO / "DESIGN.md").exists()
    assert (REPO / "README.md").exists()


def test_every_design_ref_resolves():
    assert check_design_refs.check(REPO) == 0


def test_src_actually_cites_design():
    # the contract is meaningful only if citations exist (planner, optim,
    # configs, collectives at minimum)
    refs = check_design_refs.collect_refs(REPO)
    cited_files = {str(f) for f, _, _ in refs}
    for expect in ("src/repro/core/collectives.py",
                   "src/repro/core/planner.py",
                   "src/repro/optim/__init__.py",
                   "src/repro/configs/__init__.py"):
        assert expect in cited_files, f"{expect} lost its DESIGN.md citation"


def test_checker_cli_exit_code():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_design_refs.py"),
         "--root", str(REPO)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK:" in proc.stdout
