"""core.dataplane hypothesis property tests (optional dev dep).

Kept separate from tests/test_dataplane.py so the deterministic executor
coverage runs on every environment; only THIS module skips without
hypothesis.
"""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from conftest import dict_aggregate
from repro.core import aggops, dataplane, kvagg
from repro.core.dataplane import CascadePlan, LevelSpec

EMPTY = int(kvagg.EMPTY_KEY)


def _got(res):
    keys = np.asarray(res.keys)
    vals = np.asarray(res.values)
    return {int(k): float(v) for k, v in zip(keys, vals) if k != EMPTY}


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 200),
    variety=st.integers(1, 64),
    caps=st.lists(st.sampled_from([1, 4, 16, 64]), min_size=1, max_size=4),
    ways=st.sampled_from([1, 2, 4]),
    op=st.sampled_from(sorted(aggops.names())),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_cascade_equals_grouped_combine(n, variety, caps, ways, op, seed):
    """For ANY level count / capacity split and EVERY registered AggOp, the
    finalized cascade output grouped by key equals the grouped-by-key
    combine of the raw input."""
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, variety, size=n).astype(np.int32))
    vals = jnp.asarray(r.integers(-8, 8, size=n).astype(np.float32))
    plan = CascadePlan(op=op, levels=tuple(LevelSpec(c, ways=ways) for c in caps))
    res = dataplane.run_cascade(keys, vals, plan)
    got = _got(res)
    want = dict_aggregate(keys, vals, op=op)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)
    # telemetry invariants: levels chain, evictions bounded by traffic
    li = np.asarray(res.level_in)
    lo = np.asarray(res.level_out)
    le = np.asarray(res.level_evict)
    assert li[0] == n
    np.testing.assert_array_equal(li[1:], lo[:-1])
    assert int(res.n_out) == lo[-1]
    assert np.all(le <= li) and np.all(le >= 0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_deeper_cascade_never_loses_data(seed):
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, 100, size=256).astype(np.int32))
    vals = jnp.asarray(r.standard_normal(256).astype(np.float32))
    want = dict_aggregate(keys, vals)
    for depth in (1, 2, 3):
        plan = CascadePlan(op="sum", levels=(LevelSpec(16),) * depth)
        got = _got(dataplane.run_cascade(keys, vals, plan))
        assert got.keys() == want.keys()
        for k in want:
            np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-4)
