"""Pallas FPE hash-combine kernel vs pure-jnp oracle.

Sweeps shapes / dtypes / table geometries / block sizes and asserts
bit-identical tables + eviction streams (interpret=True on CPU), plus
hypothesis property tests of the SwitchAgg conservation invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from conftest import dict_aggregate
from repro.kernels import ops, ref
from repro.kernels.kv_aggregate import fpe_aggregate_pallas

EMPTY = -1


def _stream(rng, n, key_variety, dtype=np.float32, pad_frac=0.0):
    keys = rng.integers(0, key_variety, size=n).astype(np.int32)
    vals = rng.standard_normal(n).astype(dtype)
    if pad_frac:
        mask = rng.random(n) < pad_frac
        keys = np.where(mask, EMPTY, keys)
        vals = np.where(mask, 0.0, vals).astype(dtype)
    return jnp.asarray(keys), jnp.asarray(vals)


@pytest.mark.parametrize(
    "n,capacity,ways,block_n",
    [
        (64, 16, 4, 32),
        (128, 16, 1, 64),   # direct-mapped
        (128, 32, 8, 128),
        (257, 64, 4, 64),   # non-divisible n -> padding path
        (512, 8, 2, 512),   # tiny table, heavy eviction
        (96, 128, 4, 32),   # table larger than stream
    ],
)
def test_kernel_matches_ref_shapes(n, capacity, ways, block_n, rng):
    keys, vals = _stream(rng, n, key_variety=max(4, capacity))
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, vals, capacity=capacity, ways=ways, block_n=block_n, interpret=True
    )
    r = ref.fpe_aggregate_ref(keys, vals, capacity=capacity, ways=ways)
    np.testing.assert_array_equal(tk, r.table_keys)
    np.testing.assert_allclose(tv, r.table_values, rtol=0, atol=0)
    np.testing.assert_array_equal(ek, r.evict_keys)
    np.testing.assert_allclose(ev, r.evict_values, rtol=0, atol=0)


@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_kernel_matches_ref_ops(op, rng):
    keys, vals = _stream(rng, 128, key_variety=16)
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, vals, capacity=16, ways=4, op=op, block_n=64, interpret=True
    )
    r = ref.fpe_aggregate_ref(keys, vals, capacity=16, ways=4, op=op)
    np.testing.assert_array_equal(tk, r.table_keys)
    np.testing.assert_allclose(tv, r.table_values)
    np.testing.assert_array_equal(ek, r.evict_keys)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16, np.int32])
def test_kernel_matches_ref_dtypes(dtype, rng):
    keys = jnp.asarray(rng.integers(0, 32, size=128).astype(np.int32))
    if dtype is np.int32:
        vals = jnp.asarray(rng.integers(-100, 100, size=128).astype(np.int32))
    else:
        vals = jnp.asarray(rng.standard_normal(128)).astype(dtype)
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, vals, capacity=16, ways=4, block_n=64, interpret=True
    )
    r = ref.fpe_aggregate_ref(keys, vals, capacity=16, ways=4)
    np.testing.assert_array_equal(tk, r.table_keys)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(r.table_values))
    np.testing.assert_array_equal(ek, r.evict_keys)


def test_kernel_padded_stream(rng):
    """EMPTY_KEY (padding) inputs must be skipped without touching the table."""
    keys, vals = _stream(rng, 160, key_variety=12, pad_frac=0.3)
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, vals, capacity=16, ways=4, block_n=32, interpret=True
    )
    r = ref.fpe_aggregate_ref(keys, vals, capacity=16, ways=4)
    np.testing.assert_array_equal(tk, r.table_keys)
    np.testing.assert_array_equal(ek, r.evict_keys)
    # No padded key may appear in outputs as a real entry.
    assert not np.any(np.asarray(ev)[np.asarray(ek) == EMPTY])


def test_two_level_node_conservation(rng):
    """SwitchAgg invariant: FPE flush + BPE output == exact group-by-key."""
    keys, vals = _stream(rng, 256, key_variety=48)
    out = ops.two_level_aggregate(keys, vals, capacity=16, ways=4,
                                  block_n=64, interpret=True)
    got = dict_aggregate(out.out_keys, out.out_values)
    want = dict_aggregate(keys, vals)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5)
    # every output key unique after the BPE combine? not necessarily the FPE
    # table + BPE overlap -> but n_out counts real pairs:
    assert int(out.n_in) == 256
    assert int(out.n_out) == int(np.sum(np.asarray(out.out_keys) != EMPTY))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 200),
    variety=st.integers(1, 64),
    capacity=st.sampled_from([4, 8, 16, 64]),
    ways=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_conservation(n, variety, capacity, ways, seed):
    """For any stream, the two-level node neither loses nor double-counts."""
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, variety, size=n).astype(np.int32))
    vals = jnp.asarray(r.integers(-8, 8, size=n).astype(np.float32))
    out = ops.two_level_aggregate(keys, vals, capacity=capacity, ways=ways,
                                  block_n=64, interpret=True)
    got = dict_aggregate(out.out_keys, out.out_values)
    want = dict_aggregate(keys, vals)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(16, 128),
    variety=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernel_equals_scan_ref(n, variety, seed):
    """Pallas kernel is bit-identical to the sequential-scan reference."""
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, variety, size=n).astype(np.int32))
    vals = jnp.asarray(r.standard_normal(n).astype(np.float32))
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, vals, capacity=8, ways=2, block_n=32, interpret=True
    )
    ro = ref.fpe_aggregate_ref(keys, vals, capacity=8, ways=2)
    np.testing.assert_array_equal(tk, ro.table_keys)
    np.testing.assert_allclose(tv, ro.table_values)
    np.testing.assert_array_equal(ek, ro.evict_keys)
    np.testing.assert_allclose(ev, ro.evict_values)


@pytest.mark.parametrize("op,lanes", [("mean", 2)])
def test_kernel_multilane_single_pass(op, lanes, rng):
    """Multi-lane carried ops run in ONE pallas_call (values [n, lanes],
    lane-carrying VMEM table) and stay bit-identical to the jnp scan."""
    from repro.core import aggops, kvagg

    keys = jnp.asarray(rng.integers(0, 24, size=200).astype(np.int32))
    raw = jnp.asarray(rng.standard_normal(200).astype(np.float32))
    vals = aggops.get(op).prepare_values(raw)
    assert vals.shape == (200, lanes)
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, vals, capacity=16, ways=4, op=op, block_n=64, interpret=True)
    r = kvagg.fpe_aggregate(keys, vals, capacity=16, ways=4, op=op)
    np.testing.assert_array_equal(tk, r.table_keys)
    np.testing.assert_allclose(tv, r.table_values, rtol=0, atol=0)
    np.testing.assert_array_equal(ek, r.evict_keys)
    np.testing.assert_allclose(ev, r.evict_values, rtol=0, atol=0)
    assert tv.shape == (16, lanes) and ev.shape == (200, lanes)


def test_kernel_fast_mode_matches_jnp_fast_tables(rng):
    """exact_stream=False: the kernel consumes the same pre-combined
    distinct stream as the jnp closed form, so the resident tables agree
    and conservation holds through the pallas fast path."""
    from conftest import dict_aggregate
    from repro.core import kvagg

    keys = jnp.asarray(rng.integers(0, 40, size=300).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(300).astype(np.float32))
    tkp, tvp, ekp, evp = fpe_aggregate_pallas(
        keys, vals, capacity=16, ways=4, block_n=64, interpret=True,
        exact_stream=False)
    fj = kvagg.fpe_aggregate(keys, vals, capacity=16, ways=4,
                             exact_stream=False)
    np.testing.assert_array_equal(tkp, fj.table_keys)
    np.testing.assert_allclose(np.asarray(tvp), np.asarray(fj.table_values),
                               rtol=1e-6, atol=1e-6)
    got = dict_aggregate(np.concatenate([np.asarray(tkp), np.asarray(ekp)]),
                         np.concatenate([np.asarray(tvp), np.asarray(evp)]))
    want = dict_aggregate(keys, vals)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5)


def test_eviction_rate_drops_with_capacity(rng):
    """Paper Fig. 2a mechanism: more capacity -> fewer evictions."""
    keys, vals = _stream(rng, 512, key_variety=256)
    rates = []
    for cap in (8, 64, 512):
        _, _, ek, _ = fpe_aggregate_pallas(
            keys, vals, capacity=cap, ways=4, block_n=128, interpret=True
        )
        rates.append(float(np.mean(np.asarray(ek) != EMPTY)))
    assert rates[0] > rates[1] > rates[2]
