"""Shared fixtures/helpers.

NOTE: no XLA_FLAGS here — unit/smoke tests see the 1 real CPU device.
Multi-device integration tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax
(tests/drivers/*.py).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
DRIVERS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "drivers")


def run_driver(name: str, *args: str, devices: int = 8, timeout: int = 420):
    """Run tests/drivers/<name>.py in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(DRIVERS, name + ".py"), *args],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"driver {name} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def dict_aggregate(keys, values, op="sum"):
    """Brute-force python oracle: group values by key, reduce with ``op``.

    Covers every registered AggOp (repro.core.aggops) so cascade tests can
    compare any op's *finalized* output against first-principles semantics.
    """
    groups = {}
    for k, v in zip(np.asarray(keys).tolist(), np.asarray(values).tolist()):
        if k == -1:
            continue
        groups.setdefault(k, []).append(v)
    reducers = {
        "sum": np.sum,
        "max": np.max,
        "min": np.min,
        "count": len,
        "mean": np.mean,
        "logsumexp": lambda xs: float(
            np.logaddexp.reduce(np.asarray(xs, np.float64))),
    }
    f = reducers[op]
    return {k: f(vs) for k, vs in groups.items()}
