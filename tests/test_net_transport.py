"""Transport invariants under arbitrary loss (hypothesis; DESIGN.md §7).

THE exactly-once property: whatever the loss pattern, go-back-N retransmit
plus switch-side PSN dedupe delivers every record and combines it exactly
once — the simulated totals equal the lossless ``run_cascade`` result for
every registered AggOp.  Kept separate from the deterministic simulator
tests so only this module skips when hypothesis is absent.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import aggops, dataplane, kvagg
from repro.net import sim as netsim

EMPTY = int(kvagg.EMPTY_KEY)

# small, fixed geometry: hypothesis explores the LOSS space, not the plan
# space (tests/test_dataplane_properties.py owns that), so the jit cache
# stays warm across examples
_CFG = netsim.NetConfig(records_per_packet=16, window=4)
_CAPS = (16, 8)
_FANINS = (2, 2)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 160),
    variety=st.integers(1, 32),
    loss_rate=st.floats(0.0, 0.6),
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from(sorted(aggops.names())),
)
def test_property_exactly_once_under_any_loss(n, variety, loss_rate, seed, op):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, variety, size=n).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    plan = dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=c) for c in _CAPS))
    cfg = dataclasses.replace(_CFG, loss_rate=loss_rate, seed=seed)
    from repro.net import simulate
    res = simulate(netsim.JobSpec(keys=keys, values=vals, fanins=_FANINS,
                                  plan=plan, cfg=cfg))
    ref = dataplane.run_cascade(jnp.asarray(keys), jnp.asarray(vals), plan)
    want = {int(k): np.asarray(v) for k, v in
            zip(np.asarray(ref.keys), np.asarray(ref.values)) if k != EMPTY}
    got = dict(zip(res.delivered_keys.tolist(), res.delivered_values))
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-3, atol=1e-4,
                                   err_msg=f"op={op} key={k} loss={loss_rate}")
    if loss_rate == 0.0:
        assert res.packets_dropped == 0 and res.retransmissions == 0
    # every dropped transmission of a PSN forces a later retransmission of
    # that PSN; none may vanish silently
    assert res.retransmissions >= res.packets_dropped
