"""Rack-scale fat-tree topology + aggregation-tree placement (DESIGN.md §9).

Covers the FatTreeTopology factory (tiers, oversubscribed uplink rates,
degenerate collapse to the flat Topology), the SOAR-style placement search
(greedy == exhaustive on the small fabrics, the 1:1 ToR-only and
zero-budget host-only edge cases), the placement threading through
ConfigureMsg/ExchangePlan into the cascade dataplane, and the packet-level
multi-rack incast (exact delivery under every placement, the JCT ordering
full-tree <= ToR-only <= host-only on an oversubscribed fabric).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import dataplane
from repro.core import planner as pl
from repro.core import reduction_model as rm


def _ft(**kw):
    base = dict(pods=4, tors_per_pod=4, hosts_per_tor=8,
                oversubscription=4.0, table_pairs=2048)
    base.update(kw)
    return pl.FatTreeTopology(**base)


# ---------------------------------------------------------------------------
# Topology factory.
# ---------------------------------------------------------------------------


def test_fat_tree_tiers_and_rates():
    ft = _ft()
    assert ft.n_hosts == 128 and ft.n_tors == 16
    assert ft.axes == ("edge", "aggr", "core")
    assert ft.fanins == (8, 4, 4)
    # 8 hosts x 1.25 GB/s through a 4:1 oversubscribed ToR uplink
    assert ft.uplink_gbps == pytest.approx(8 * 1.25 / 4)
    assert ft.core_gbps == pytest.approx(4 * ft.uplink_gbps / 4)
    assert len(ft.tier_switches("tor")) == 16
    assert len(ft.tier_switches("agg")) == 4
    assert len(ft.tier_switches("core")) == 1


def test_single_rack_degenerates_to_flat_topology():
    # one pod, one ToR: the fat-tree IS the pre-§9 flat single-level
    # Topology — same axes, fanins, rates, scarce-axis machinery
    ft = pl.FatTreeTopology(pods=1, tors_per_pod=1, hosts_per_tor=8,
                            edge_gbps=1.25)
    flat = pl.Topology(links=(pl.LinkBudget(axis="edge", fanin=8,
                                            gbps=1.25),))
    assert ft.to_topology() == flat
    assert ft.tree().axes == ("edge",)
    assert ft.tree().fanin == 8
    # no fabric uplinks: the scarce resource is the reducer in-link
    assert ft.scarce_uplink_axis() == "reducer"


def test_oversubscription_must_be_downlink_to_uplink():
    with pytest.raises(ValueError):
        _ft(oversubscription=0.5)
    with pytest.raises(ValueError):
        _ft(table_pairs=-1)
    with pytest.raises(ValueError):
        _ft(tier_table_pairs=(("spine", 64),))


def test_tier_table_overrides():
    ft = _ft(table_pairs=512, tier_table_pairs=(("core", 8192),))
    assert ft.switch_table("tor") == 512
    assert ft.switch_table("core") == 8192


# ---------------------------------------------------------------------------
# Placement search.
# ---------------------------------------------------------------------------


def _place(ft, policy, *, pairs=512, variety=2048):
    return pl.place_aggregation_tree(ft, per_host_pairs=pairs,
                                     key_variety=variety, policy=policy)


def test_one_to_one_oversubscription_picks_tor_only():
    # non-blocking fabric: only the ToR uplink tier is reducible AND
    # scarce, so the search stops after the ToR tier — deeper placement
    # buys no scarce-uplink bytes
    ft = _ft(oversubscription=1.0)
    for policy in ("greedy", "exhaustive", "auto"):
        p = _place(ft, policy)
        assert p.tiers == ("tor",), (policy, p.tiers)
        assert p.scarce_axis == "aggr"
        assert p.n_agg_switches == 16


def test_zero_switch_budget_falls_back_to_host_aggregation():
    ft = _ft(table_pairs=0)
    for policy in ("greedy", "exhaustive", "auto", "full", "tor_only"):
        p = _place(ft, policy)
        assert p.tiers == ()
        assert p.n_agg_switches == 0
        assert not any(p.level_enabled)
    # a zero-budget placement must behave exactly like host_only
    host = _place(ft, "host_only")
    assert _place(ft, "auto").tier_bytes == host.tier_bytes


def test_search_beats_or_matches_fixed_policies_on_scarce_bytes():
    for oversub in (1.0, 2.0, 4.0, 8.0):
        ft = _ft(oversubscription=oversub)
        ex = _place(ft, "exhaustive")
        for fixed in ("host_only", "tor_only", "full"):
            assert ex.scarce_uplink_bytes <= \
                _place(ft, fixed).scarce_uplink_bytes + 1e-9, (oversub, fixed)


def test_greedy_matches_exhaustive_on_small_fabrics():
    for oversub in (1.0, 4.0):
        for pods in (1, 2, 4):
            ft = _ft(pods=pods, oversubscription=oversub)
            g, e = _place(ft, "greedy"), _place(ft, "exhaustive")
            assert g.scarce_uplink_bytes == pytest.approx(
                e.scarce_uplink_bytes), (pods, oversub)


def test_placement_respects_per_tier_budgets():
    # ToRs have no table; the search must place around them
    ft = _ft(tier_table_pairs=(("tor", 0),))
    p = _place(ft, "full")
    assert "tor" not in p.tiers and p.level_enabled[0] is False
    assert p.level_capacities[0] == 0


def test_tor_aggregation_cuts_uplink_bytes_in_model():
    ft = _ft()
    host = pl.fat_tree_tier_bytes(ft, (), per_host_pairs=512,
                                  key_variety=2048)
    tor = pl.fat_tree_tier_bytes(ft, ("tor",), per_host_pairs=512,
                                 key_variety=2048)
    assert tor["edge"] == host["edge"]  # mapper emissions are fixed
    assert tor["aggr"] < host["aggr"]
    assert tor["core"] < host["core"]
    assert tor["reducer"] < host["reducer"]


# ---------------------------------------------------------------------------
# Threading: placement -> ConfigureMsg/ExchangePlan -> cascade plans.
# ---------------------------------------------------------------------------


def test_plan_fat_tree_job_carries_placement():
    ft = _ft()
    req = pl.LaunchRequest(job_id=3, n_workers=ft.n_hosts,
                           expected_pairs=512, key_variety=2048)
    jp = pl.plan_fat_tree_job(ft, req, policy="full")
    assert jp.configure.level_axes == ("edge", "aggr", "core")
    assert jp.configure.level_capacities == (2048, 2048, 2048)
    assert jp.configure.level_enabled == (True, True, True)
    assert jp.exchange.level_capacities == jp.configure.level_capacities
    assert jp.exchange.placement_policy == "full"
    assert jp.exchange.scarce_link_bytes < jp.flat_scarce_bytes
    assert 0.0 < jp.exchange.predicted_root_reduction <= 1.0

    cascade = dataplane.plan_from_configure(jp.configure)
    assert cascade.capacities == (2048, 2048, 2048)
    assert all(l.enabled for l in cascade.levels)


def test_plan_from_configure_placement_overrides_even_split():
    cfg = pl.ConfigureMsg(tree_id=0, level_axes=("edge", "aggr", "core"),
                          fanins=(8, 4, 4), fpe_capacity=999, op="max",
                          level_capacities=(128, 0, 512),
                          level_enabled=(True, False, True))
    plan = dataplane.plan_from_configure(cfg)
    assert plan.op == "max"
    assert plan.capacities == (128, 0, 512)
    assert [l.enabled for l in plan.levels] == [True, False, True]
    # without the placement fields the legacy even split still rules
    legacy = dataplane.plan_from_configure(dataclasses.replace(
        cfg, level_capacities=(), level_enabled=()))
    assert legacy.capacities == (333, 333, 333)


def test_cascade_from_exchange_plan_uses_trailing_placement_levels():
    x = pl.ExchangePlan(
        mode=pl.GradAggMode.TREE, leaf_axis="edge",
        upper_axes=("aggr", "core"), k_fraction=0.01, fpe_capacity=4096,
        predicted_root_reduction=0.0, predicted_kv_reduction=0.0,
        level_capacities=(2048, 1024, 512),
        level_enabled=(True, False, True))
    plan = dataplane.cascade_from_exchange_plan(x)
    assert plan.capacities == (1024, 512)
    assert [l.enabled for l in plan.levels] == [False, True]


def test_disabled_level_forwards_in_run_cascade():
    keys = np.asarray(rm.zipf_keys(1024, 128, seed=1), np.int32)
    vals = np.ones((1024,), np.float32)
    full = dataplane.CascadePlan(op="sum", levels=(
        dataplane.LevelSpec(capacity=64),
        dataplane.LevelSpec(capacity=64)))
    gated = dataplane.CascadePlan(op="sum", levels=(
        dataplane.LevelSpec(capacity=64),
        dataplane.LevelSpec(capacity=64, enabled=False)))
    r_full = dataplane.run_cascade(keys, vals, full)
    r_gated = dataplane.run_cascade(keys, vals, gated)
    # a forward-only hop: out == in at that level, no evictions
    assert int(r_gated.level_out[1]) == int(r_gated.level_in[1])
    assert int(r_gated.level_evict[1]) == 0
    # and it never changes totals: final grouped tables agree
    def table(r):
        k, v = np.asarray(r.keys), np.asarray(r.values)
        return dict(zip(k[k != -1].tolist(), v[: len(k)][k != -1].tolist()))
    assert table(r_full) == pytest.approx(table(r_gated))


def test_levelstate_disabled_is_pure_relay():
    spec = dataplane.LevelSpec(capacity=64, enabled=False)
    st = dataplane.LevelState(spec, "sum")
    k = np.asarray([3, 3, 5, -1], np.int32)
    v = np.asarray([1.0, 2.0, 3.0, 9.0], np.float32)
    ok, ov = st.ingest(k, v)
    assert ok.tolist() == [3, 3, 5]  # unaggregated, padding dropped
    assert ov.tolist() == [1.0, 2.0, 3.0]
    fk, _ = st.flush()
    assert fk.shape[0] == 0  # nothing resident
    assert st.n_in == 3 and st.n_out == 3 and st.n_evict == 0


# ---------------------------------------------------------------------------
# Packet-level multi-rack incast.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_incast():
    from repro.net import sim as netsim

    ft = pl.FatTreeTopology(pods=2, tors_per_pod=2, hosts_per_tor=4,
                            oversubscription=4.0, table_pairs=512)
    # enough pairs that serialization on the oversubscribed uplinks
    # dominates the EoT store-and-forward flush latency (the regime the
    # placement search optimizes for; tiny streams are latency-bound)
    n = ft.n_hosts * 512
    keys = np.asarray(rm.zipf_keys(n, 512, seed=0), np.int32)
    vals = np.ones((n,), np.float32)
    cmp = netsim.fat_tree_jct_comparison(
        ft, keys, vals, per_host_pairs=512, key_variety=512,
        cfg=netsim.NetConfig(exact_stream=False))
    return ft, keys, cmp


def test_incast_exact_delivery_under_every_placement(small_incast):
    _, keys, cmp = small_incast
    want = np.bincount(keys, minlength=512)
    for pol in cmp["policies"]:
        got = cmp["_results"][pol].delivered_table()
        assert all(abs(got.get(k, 0.0) - c) < 1e-3
                   for k, c in enumerate(want) if c), pol


def test_incast_placement_orders_uplink_bytes(small_incast):
    ft, _, cmp = small_incast
    scarce = cmp["scarce_axis"]
    host = cmp["host_only"]["link_bytes"][scarce]
    tor = cmp["tor_only"]["link_bytes"][scarce]
    full = cmp["full"]["link_bytes"][scarce]
    assert full < tor < host
    # host-only forwards everything: scarce bytes == edge ingress bytes
    assert cmp["host_only"]["link_bytes"]["edge"] <= host * (1 + 1e-6) * 2


def test_incast_jct_orders_full_tor_host(small_incast):
    _, _, cmp = small_incast
    j = cmp["jct_s"]
    assert j["full"] <= j["tor_only"] <= j["host_only"]


def test_disabled_hop_telemetry_zero_proc_nonzero_bytes(small_incast):
    """Regression: a placement-disabled (forward-only) hop must still
    report its wire bytes and queue depth — zero aggregation-engine
    seconds, nonzero bytes_out — identically in both engines (forward
    relays used to skip the pending-queue accounting entirely)."""
    import dataclasses

    from repro.net import sim as netsim

    ft, keys, _ = small_incast
    vals = np.ones_like(keys, np.float32)
    placement = pl.place_aggregation_tree(
        ft, per_host_pairs=512, key_variety=512, policy="tor_only")
    assert placement.level_enabled[0] and not all(placement.level_enabled)
    cfg = netsim.NetConfig(exact_stream=True, records_per_packet=32)
    from repro.net import simulate
    res = {eng: simulate(ft, keys, vals, placement=placement,
                         cfg=dataclasses.replace(cfg, engine=eng))
           for eng in ("node", "vectorized")}
    for eng, r in res.items():
        for lvl, enabled in zip(r.per_level, placement.level_enabled):
            if enabled:
                assert lvl["agg_proc_s"] > 0.0, (eng, lvl)
                continue
            # forward-only: every record moves (bytes, queue) but the
            # aggregation engine never runs (proc seconds, evictions)
            assert lvl["agg_proc_s"] == 0.0, (eng, lvl)
            assert lvl["evictions"] == 0, (eng, lvl)
            assert lvl["bytes_out"] > 0, (eng, lvl)
            assert lvl["records_out"] == lvl["records_in"], (eng, lvl)
            assert lvl["queue_peak"] > 0, (eng, lvl)
    assert res["vectorized"].report() == res["node"].report()


def test_host_only_placement_equals_aggregate_false_baseline(small_incast):
    from repro.net import sim as netsim

    ft, keys, cmp = small_incast
    vals = np.ones_like(keys, np.float32)
    from repro.net import simulate
    base = simulate(netsim.JobSpec(
        keys=keys, values=vals, fanins=ft.fanins, aggregate=False,
        cfg=netsim.NetConfig(
            link_gbps=tuple(l.gbps for l in ft.link_tiers()),
            reducer_gbps=ft.edge_gbps, exact_stream=False),
        axes=ft.axes))
    host = cmp["_results"]["host_only"]
    assert host.jct_s == pytest.approx(base.jct_s)
    assert host.arrived_records == base.arrived_records
