"""Failure injection + epoch-restart recovery: exactly-once under switch
and link death (DESIGN.md §12).

The invariant under test: for ANY failure schedule (switch crashes,
link-down windows, table wipes) x loss rate x AggOp, the delivered table
of the surviving epoch is *bit-identical* to the same engine's no-failure
run — the epoch-restart protocol (replayed mappers, epoch-tagged packets,
Receiver cross-incarnation dedupe, forward-only bypass of dead switches)
never double-combines and never loses a record.  Both engines run the
same faulted-tier node path, so node/vectorized parity extends to JCT,
epoch count, and verdict history under failures.  The fat-tree cell
closes the control loop: a mid-job ToR crash triggers
``planner.repair_placement`` and the repaired placement finishes the job
with a measured JCT penalty.
"""

import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops
from repro.core import planner as pl
from repro.net import sim as netsim
from repro.net import simulate, transport, wire
from repro.runtime.fault_tolerance import (FailureEvent, FailureInjector,
                                           FailureVerdict, FaultPolicy)

FANINS = (4, 2)


@pytest.fixture(scope="module")
def job():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 40, size=300).astype(np.int32)
    vals = rng.integers(1, 6, size=300).astype(np.float64)
    return keys, vals


def _run(job, events, *, policy=None, engine="node", loss=0.0, op="sum"):
    keys, vals = job
    inj = FailureInjector({}, events=tuple(events))
    cfg = netsim.NetConfig(engine=engine, loss_rate=loss, seed=7)
    return simulate(netsim.JobSpec(keys=keys, values=vals, fanins=FANINS,
                                   op=op, cfg=cfg),
                    faults=inj, fault_policy=policy)


def _oracle(job, *, engine="node", loss=0.0, op="sum"):
    keys, vals = job
    cfg = netsim.NetConfig(engine=engine, loss_rate=loss, seed=7)
    return simulate(netsim.JobSpec(keys=keys, values=vals, fanins=FANINS,
                                   op=op, cfg=cfg)).delivered_table()


# ---------------------------------------------------------------------------
# Receiver: cross-incarnation epoch dedupe (the unit-level gate).
# ---------------------------------------------------------------------------


def _hdr(flow, psn, epoch, eot=False):
    return wire.PacketHeader(flow_id=flow, psn=psn, job_id=0, level=0,
                             n_records=1, eot=eot, epoch=epoch)


def test_receiver_discards_stale_epoch_packets():
    r = transport.Receiver()
    assert r.accept(_hdr(1, 0, epoch=1))  # epoch 1 announces itself
    # a leftover of the dead epoch-0 incarnation arrives late
    assert not r.accept(_hdr(1, 1, epoch=0))
    assert r.stale_epoch_discards == 1
    # and it didn't disturb the live flow's PSN cursor
    assert r.accept(_hdr(1, 1, epoch=1))


def test_receiver_epoch_bump_resets_psn_map():
    r = transport.Receiver()
    for psn in range(3):
        assert r.accept(_hdr(1, psn, epoch=0))
    # restart: the child replays from PSN 0 under the next epoch — these
    # are NOT duplicates of the dead incarnation's stream
    assert r.accept(_hdr(1, 0, epoch=1))
    assert r.duplicate_discards == 0
    assert r.epoch == 1
    # within the new epoch the plain PSN gate still dedupes
    assert not r.accept(_hdr(1, 0, epoch=1))
    assert r.duplicate_discards == 1


# ---------------------------------------------------------------------------
# Schedule plumbing: validation + seeded replayability.
# ---------------------------------------------------------------------------


def test_failure_event_validation():
    with pytest.raises(ValueError):
        FailureEvent(kind="meteor_strike", t_s=0.0, level=0, switch=0)
    with pytest.raises(ValueError):
        FailureEvent(kind="link_down", t_s=0.0, level=0, switch=0)  # no window
    with pytest.raises(ValueError):
        FailureEvent(kind="switch_crash", t_s=-1.0, level=0, switch=0)


def test_from_seed_is_replayable():
    a = FailureInjector.from_seed(5, n_events=6, fanins=FANINS, t_max_s=1e-3)
    b = FailureInjector.from_seed(5, n_events=6, fanins=FANINS, t_max_s=1e-3)
    assert a.events == b.events and a.n_events == 6
    assert all(e.kind in FailureEvent.KINDS for e in a.events)
    assert list(a.events) == sorted(a.events, key=lambda e: e.t_s)
    c = FailureInjector.from_seed(6, n_events=6, fanins=FANINS, t_max_s=1e-3)
    assert c.events != a.events


def test_fault_policy_validation():
    with pytest.raises(ValueError):
        FaultPolicy(backoff=0.5)
    with pytest.raises(ValueError):
        FaultPolicy(max_timeouts=0)
    with pytest.raises(ValueError):
        FaultPolicy(max_epochs=0)


# ---------------------------------------------------------------------------
# Exactly-once under single-fault cells (both engines).
# ---------------------------------------------------------------------------

ENGINES = ("node", "vectorized")


@pytest.mark.parametrize("engine", ENGINES)
def test_mid_job_switch_crash_exactly_once(job, engine):
    ev = [FailureEvent(kind="switch_crash", t_s=1e-6, level=0, switch=1)]
    fsr = _run(job, ev, engine=engine)
    assert fsr.epochs == 2
    assert fsr.bypass == ((0, 1),)
    # every verdict names the dead switch; both detection paths fired
    # (senders exhausting retries AND the parent's liveness timeout), and
    # the earliest one dated the restart
    assert all(v.kind == "switch_crash" and (v.level, v.switch) == (0, 1)
               for v in fsr.verdicts)
    assert {v.detected_by for v in fsr.verdicts} == {"sender", "parent"}
    assert fsr.applied[0].t_detect_s == min(v.t_detect_s
                                            for v in fsr.verdicts)
    assert fsr.delivered_table() == _oracle(job, engine=engine)
    # recovery costs time: total JCT includes the dead incarnation
    assert fsr.jct_s > fsr.result.jct_s


@pytest.mark.parametrize("engine", ENGINES)
def test_transient_link_down_recovers_without_verdict(job, engine):
    # a window shorter than the retry budget: retransmissions ride it out,
    # nobody is declared dead, no restart
    ev = [FailureEvent(kind="link_down", t_s=1e-6, level=0, switch=1,
                       child=0, duration_s=5e-5)]
    fsr = _run(job, ev, engine=engine)
    assert fsr.epochs == 1 and not fsr.verdicts
    assert fsr.delivered_table() == _oracle(job, engine=engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_long_link_down_is_declared_dead_and_bypassed(job, engine):
    # a window outlasting the retry budget: the sender's verdict is a
    # false-positive crash (indistinguishable from one) — the runtime
    # routes around the switch exactly as if it had died
    ev = [FailureEvent(kind="link_down", t_s=1e-6, level=0, switch=1,
                       child=0, duration_s=2e-2)]
    fsr = _run(job, ev, engine=engine)
    assert fsr.epochs == 2
    assert [(v.kind, v.detected_by) for v in fsr.applied] \
        == [("link_down", "sender")]
    assert fsr.bypass == ((0, 1),)
    assert fsr.delivered_table() == _oracle(job, engine=engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_table_wipe_restarts_without_bypass(job, engine):
    ev = [FailureEvent(kind="table_wipe", t_s=2e-6, level=0, switch=1)]
    fsr = _run(job, ev, engine=engine)
    assert fsr.epochs == 2
    assert [(v.kind, v.detected_by) for v in fsr.verdicts] \
        == [("table_wipe", "self")]
    # the switch survives: no bypass, and the next epoch exercises the
    # Receiver's epoch-bump dedupe on the same incarnation of the node
    assert fsr.bypass == ()
    assert fsr.delivered_table() == _oracle(job, engine=engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_root_crash_detected_by_reducer(job, engine):
    ev = [FailureEvent(kind="switch_crash", t_s=1e-6,
                       level=len(FANINS) - 1, switch=0)]
    fsr = _run(job, ev, engine=engine)
    assert fsr.epochs == 2
    assert any(v.detected_by == "parent" and v.level == len(FANINS) - 1
               for v in fsr.applied)
    assert fsr.delivered_table() == _oracle(job, engine=engine)


def test_two_level_crash_cascade_restarts_twice(job):
    # crashes at both tiers: only the earliest-detected verdict is applied
    # per restart (the later failure had not been detected yet) — two
    # restarts, both switches bypassed, still exactly-once
    ev = [FailureEvent(kind="switch_crash", t_s=1e-6, level=0, switch=0),
          FailureEvent(kind="switch_crash", t_s=1e-6, level=1, switch=0)]
    fsr = _run(job, ev)
    assert fsr.epochs == 3
    assert fsr.bypass == ((0, 0), (1, 0))
    assert fsr.delivered_table() == _oracle(job)


def test_max_epochs_exhaustion_raises(job):
    ev = [FailureEvent(kind="switch_crash", t_s=1e-6, level=0, switch=0),
          FailureEvent(kind="switch_crash", t_s=1e-6, level=1, switch=0)]
    with pytest.raises(RuntimeError, match="did not quiesce"):
        _run(job, ev, policy=FaultPolicy(max_epochs=1))


def test_verdicts_carry_absolute_detection_times(job):
    ev = [FailureEvent(kind="switch_crash", t_s=1e-6, level=0, switch=1)]
    fsr = _run(job, ev)
    for v in fsr.verdicts:
        assert isinstance(v, FailureVerdict)
        assert v.t_detect_s > 1e-6  # detection strictly after the failure
    assert fsr.epoch_log[-1]["n_verdicts"] == 0  # final epoch ran clean


# ---------------------------------------------------------------------------
# The sweep: schedule x loss x op x engine, vs the no-failure oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_seeded_schedules_exactly_once_every_op(job, op):
    """Seeded random schedules under 3% loss: for every AggOp the
    delivered table is bit-identical to the same engine's no-failure run,
    and the two engines agree on tables, JCT, and epoch count.

    Bit-identity (``==``, not allclose) holds even for the
    float-order-sensitive ops here because the surviving epoch replays
    the full mapper streams through the same combine schedule as a clean
    run; the python brute-force oracle is checked allclose (wire floats
    are float32)."""
    keys, vals = job
    want_py = dict_aggregate(keys, vals, op)
    for seed in (1, 3):
        inj = FailureInjector.from_seed(seed, n_events=3, fanins=FANINS,
                                        t_max_s=6e-6)
        runs = {}
        for engine in ENGINES:
            cfg = netsim.NetConfig(engine=engine, loss_rate=0.03, seed=11)
            fsr = simulate(netsim.JobSpec(keys=keys, values=vals,
                                          fanins=FANINS, op=op, cfg=cfg),
                           faults=inj)
            assert fsr.delivered_table() == _oracle(
                job, engine=engine, loss=0.03, op=op)
            runs[engine] = fsr
        rn, rv = runs["node"], runs["vectorized"]
        assert rn.epochs > 1  # these seeds do fire mid-job (pinned)
        assert rn.delivered_table() == rv.delivered_table()
        assert rn.jct_s == rv.jct_s and rn.epochs == rv.epochs
        assert [(v.kind, v.level, v.switch, v.t_detect_s)
                for v in rn.verdicts] \
            == [(v.kind, v.level, v.switch, v.t_detect_s)
                for v in rv.verdicts]
        got = rn.delivered_table()
        assert got.keys() == want_py.keys()
        for k in want_py:
            np.testing.assert_allclose(got[k], want_py[k],
                                       rtol=1e-4, atol=1e-5)


def test_property_any_schedule_exactly_once(job):
    """Hypothesis sweep (dev-only dep): arbitrary (schedule seed, event
    count, loss rate) keep the exactly-once invariant on both engines."""
    hyp = pytest.importorskip(
        "hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
    st = pytest.importorskip("hypothesis.strategies")
    keys, vals = job

    @hyp.settings(deadline=None, max_examples=15)
    @hyp.given(seed=st.integers(0, 2**16), n_events=st.integers(1, 4),
               loss_pm=st.integers(0, 50))
    def check(seed, n_events, loss_pm):
        loss = loss_pm / 1000.0
        inj = FailureInjector.from_seed(seed, n_events=n_events,
                                        fanins=FANINS, t_max_s=6e-6)
        for engine in ENGINES:
            cfg = netsim.NetConfig(engine=engine, loss_rate=loss, seed=seed)
            spec = netsim.JobSpec(keys=keys, values=vals, fanins=FANINS,
                                  cfg=cfg)
            fsr = simulate(spec, faults=inj)
            want = simulate(spec).delivered_table()
            assert fsr.delivered_table() == want

    check()


# ---------------------------------------------------------------------------
# repair_placement: the control plane's half of recovery.
# ---------------------------------------------------------------------------


def _small_ft(**kw):
    base = dict(pods=2, tors_per_pod=2, hosts_per_tor=4,
                oversubscription=2.0, table_pairs=512)
    base.update(kw)
    return pl.FatTreeTopology(**base)


def test_bypass_byte_walk_reduces_to_uniform_walk():
    ft = _small_ft()
    plc = pl.place_aggregation_tree(ft, per_host_pairs=64, key_variety=64,
                                    policy="full")
    uniform = pl.fat_tree_tier_bytes(ft, plc.tiers,
                                     per_host_pairs=64, key_variety=64)
    walked = pl.fat_tree_tier_bytes_with_bypass(
        ft, plc.tiers, [], per_host_pairs=64, key_variety=64)
    for ax in uniform:
        assert walked[ax] == pytest.approx(uniform[ax])


def test_repair_partial_tier_death_bypasses_in_place():
    ft = _small_ft()
    plc = pl.place_aggregation_tree(ft, per_host_pairs=64, key_variety=64,
                                    policy="full")
    rep = pl.repair_placement(ft, plc, failed=[(0, 2)],
                              per_host_pairs=64, key_variety=64)
    assert rep.failed == ((0, 2),)
    assert rep.bypass == ((0, 2),)  # tier survives, dead switch relays
    assert rep.dropped_tiers == ()
    assert "edge" in rep.degraded_axes
    # a bypassed ToR forwards its subtree unreduced: never cheaper
    assert rep.extra_scarce_bytes >= 0.0
    assert rep.extra_reducer_bytes >= 0.0
    assert rep.placement.policy.startswith("repair(")


def test_repair_whole_tier_death_replaces_around_it():
    ft = _small_ft()
    plc = pl.place_aggregation_tree(ft, per_host_pairs=64, key_variety=64,
                                    policy="full")
    rep = pl.repair_placement(ft, plc,
                              failed=[(0, s) for s in range(ft.n_tors)],
                              per_host_pairs=64, key_variety=64)
    assert "tor" in rep.dropped_tiers  # re-placed around wholesale
    assert "tor" not in rep.placement.tiers
    assert rep.bypass == ()  # nothing left to bypass in a dropped tier


def test_repair_rejects_bad_coordinates():
    ft = _small_ft()
    plc = pl.place_aggregation_tree(ft, per_host_pairs=64, key_variety=64,
                                    policy="full")
    with pytest.raises(ValueError):
        pl.repair_placement(ft, plc, failed=[(9, 0)],
                            per_host_pairs=64, key_variety=64)


# ---------------------------------------------------------------------------
# Fat-tree end to end: mid-job ToR crash -> repair -> finish (both engines).
# ---------------------------------------------------------------------------


def test_fat_tree_tor_crash_repairs_and_finishes():
    ft = _small_ft()
    rng = np.random.default_rng(0)
    n = ft.n_hosts * 40
    keys = rng.integers(0, 64, size=n).astype(np.int32)
    vals = rng.integers(1, 5, size=n).astype(np.float64)
    want = dict_aggregate(keys, vals, "sum")

    base = simulate(ft, keys, vals, policy="full", cfg=netsim.NetConfig())
    # crash a ToR inside the tier-0 busy window (the clean JCT is
    # reducer-drain dominated, so "mid-job" for a ToR is early)
    inj = FailureInjector({}, events=(FailureEvent(
        kind="switch_crash", t_s=base.jct_s * 1e-3, level=0, switch=2),))
    runs = {}
    for engine in ENGINES:
        fsr = simulate(ft, keys, vals, faults=inj, policy="full",
                       cfg=netsim.NetConfig(engine=engine))
        assert fsr.epochs == 2
        assert fsr.bypass == ((0, 2),)
        # the control plane was in the loop: a repair rode back
        assert fsr.repair is not None
        assert fsr.repair.failed == ((0, 2),)
        assert "edge" in fsr.repair.degraded_axes
        # exactly-once through crash + re-placement
        assert fsr.delivered_table() == want
        # and the recovery has a measurable JCT penalty
        assert fsr.jct_s > base.jct_s
        runs[engine] = fsr
    rn, rv = runs["node"], runs["vectorized"]
    assert rn.jct_s == rv.jct_s and rn.epochs == rv.epochs
    assert rn.delivered_table() == rv.delivered_table()
