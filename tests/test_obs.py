"""Observability layer: tracing, metrics registry, dashboard (DESIGN.md §11).

Pins the three contracts the obs layer ships with:

* **Chrome-trace schema** — ``Tracer.to_chrome()`` is loadable trace-event
  JSON (Perfetto), with well-formed nesting per (pid, tid) lane and every
  simulated-time span inside ``[0, jct]``;
* **zero overhead when disabled** — a disabled tracer records nothing,
  hands out the no-op singleton, and allocates zero bytes inside
  ``repro.obs.trace`` (the throughput side of the same contract is
  floor-gated by ``bench_sim.py``'s ``obs_overhead`` cell);
* **telemetry parity** — the node and vectorized sim engines publish
  bit-identical metric series for the same job, loss included: the
  DESIGN.md §10 parity contract extended to telemetry.
"""

import dataclasses
import json
import tracemalloc

import numpy as np
import pytest

from repro.core import dataplane, planner
from repro.core import reduction_model as rm
from repro.net import sim as netsim
from repro.net import simulate
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace


def _small_plan(caps=(32, 32), op="sum"):
    return dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=c) for c in caps))


def _run_small_job(tag="job"):
    keys = rm.zipf_keys(256, 64, skew=0.99, seed=0).astype(np.int32)
    vals = np.ones((256,), np.float32)
    return simulate(netsim.JobSpec(
        keys=keys, values=vals, fanins=(2, 2), plan=_small_plan(),
        cfg=netsim.NetConfig(records_per_packet=8, exact_stream=True),
        tag=tag))


def _run_lossy_fat_tree(engine):
    """A lossy fat-tree job — retransmit/gap/duplicate series non-zero."""
    ft = planner.FatTreeTopology(pods=4, tors_per_pod=2, hosts_per_tor=2,
                                 oversubscription=4.0, table_pairs=256)
    n = ft.n_hosts * 16
    keys = rm.zipf_keys(n, 64, skew=0.99, seed=1).astype(np.int32)
    vals = np.ones((n,), np.float32)
    placement = planner.place_aggregation_tree(
        ft, per_host_pairs=16, key_variety=64, policy="full")
    cfg = netsim.NetConfig(records_per_packet=4, exact_stream=True,
                           loss_rate=0.02, seed=3, window=4, engine=engine)
    return simulate(ft, keys, vals, placement=placement, cfg=cfg)


# -- trace export schema ----------------------------------------------------

def test_trace_chrome_export_schema():
    with obs_trace.scoped_tracer() as tr:
        with tr.span("outer", cat="wall", args={"k": 1}):
            with tr.span("inner", cat="wall"):
                pass
        pid = tr.new_track("sim test")
        tr.name_thread(pid, 0, "L0 transport")
        tr.add_span("transport", 0.0, 1.5e-3, cat="sim.transport", pid=pid)
        tr.instant("mark", t_s=1e-3, pid=pid)
        doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    json.loads(json.dumps(doc))  # round-trips as JSON
    # metadata names the wall-clock process and the sim track
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"name": "process_name", "ph": "M", "pid": obs_trace.WALL_PID,
            "tid": 0, "args": {"name": "wall-clock"}} in meta
    assert any(e["name"] == "process_name" and e["pid"] == pid
               for e in meta)
    assert any(e["name"] == "thread_name"
               and e["args"]["name"] == "L0 transport" for e in meta)
    for e in evs:
        if e["ph"] == "M":  # metadata events carry no timestamp
            assert {"name", "pid", "tid", "args"} <= e.keys()
            continue
        assert {"name", "ph", "ts", "pid", "tid"} <= e.keys()
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
            assert "cat" in e
    # virtual-time spans are exported in microseconds
    tx = next(e for e in evs if e["name"] == "transport")
    assert tx["ts"] == 0.0 and tx["dur"] == pytest.approx(1.5e3)


def _assert_well_nested(events):
    """Per (pid, tid) lane, "X" spans either nest or are disjoint."""
    lanes = {}
    for e in events:
        if e["ph"] == "X":
            lanes.setdefault((e["pid"], e["tid"]), []).append(
                (e["ts"], e["ts"] + e["dur"]))
    assert lanes
    eps = 1e-6
    for lane, spans in lanes.items():
        for i, (a0, a1) in enumerate(spans):
            for b0, b1 in spans[i + 1:]:
                disjoint = a1 <= b0 + eps or b1 <= a0 + eps
                nested = ((a0 >= b0 - eps and a1 <= b1 + eps)
                          or (b0 >= a0 - eps and b1 <= a1 + eps))
                assert disjoint or nested, (
                    f"partial overlap on lane {lane}: "
                    f"[{a0},{a1}] vs [{b0},{b1}]")


def test_sim_trace_spans_within_jct_and_well_nested():
    with obs_trace.scoped_tracer() as tr:
        res = _run_small_job()
        events = [e for e in tr.events]
    sim_events = [e for e in events if e["pid"] >= 1]
    assert sim_events, "sim run recorded no virtual-time spans"
    jct_us = res.jct_s * 1e6
    for e in sim_events:
        assert e["ts"] >= -1e-6
        assert e["ts"] + e.get("dur", 0.0) <= jct_us * (1 + 1e-9) + 1e-6
    _assert_well_nested(events)


def test_each_sim_run_gets_its_own_track():
    with obs_trace.scoped_tracer() as tr:
        _run_small_job(tag="a")
        _run_small_job(tag="b")
        pids = {e["pid"] for e in tr.events if e["pid"] >= 1}
        names = [m["args"]["name"] for m in tr._meta
                 if m["name"] == "process_name"]
    assert len(pids) == 2
    assert any("a" in n for n in names) and any("b" in n for n in names)


# -- disabled tracer: the zero-overhead contract ----------------------------

def test_disabled_tracer_records_nothing_and_reuses_singleton():
    tr = obs_trace.Tracer()  # disabled by default
    s1 = tr.span("x", cat="y", args={"big": list(range(10))})
    s2 = tr.span("z")
    assert s1 is s2 is obs_trace._NULL_SPAN
    with s1:
        pass
    tr.add_span("a", 0.0, 1.0)
    tr.add_wall_span("b", 0.0, 1.0)
    tr.instant("c")
    tr.name_thread(1, 0, "lane")
    assert tr.events == []
    assert tr._meta == []
    assert tr.to_chrome()["traceEvents"][1:] == []  # wall meta only


def test_disabled_tracer_allocates_zero_bytes():
    tr = obs_trace.Tracer()
    for _ in range(5):  # warm caches (method wrappers, etc.)
        with tr.span("warm"):
            pass
        tr.add_span("warm", 0.0, 1.0)
        tr.instant("warm")
    filt = [tracemalloc.Filter(True, obs_trace.__file__)]
    tracemalloc.start()
    try:
        snap0 = tracemalloc.take_snapshot().filter_traces(filt)
        for _ in range(200):
            with tr.span("x", cat="y"):
                pass
            tr.add_span("x", 0.0, 1.0)
            tr.add_wall_span("x", 0.0, 1.0)
            tr.instant("x")
        snap1 = tracemalloc.take_snapshot().filter_traces(filt)
    finally:
        tracemalloc.stop()
    diff = snap1.compare_to(snap0, "lineno")
    leaked = sum(s.size_diff for s in diff)
    assert leaked <= 0, f"disabled tracer allocated {leaked}B: {diff[:5]}"


# -- metrics registry -------------------------------------------------------

def test_registry_label_identity_and_kind_conflict():
    with obs_metrics.scoped() as reg:
        reg.counter("t.x_total", b="2", a="1").inc(3)
        reg.counter("t.x_total", a="1", b="2").inc(4)  # same series
        assert reg.value("t.x_total", a="1", b="2") == 7.0
        reg.gauge("t.g_s", job="j").set(1.5)
        assert reg.value("t.g_s", job="j") == 1.5
        h = reg.histogram("t.h")
        h.observe(1.0)
        h.observe(3.0)
        snap = reg.value("t.h")
        assert snap == {"count": 2, "sum": 4.0, "min": 1.0, "max": 3.0,
                        "mean": 2.0}
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("t.x_total")


def test_collect_is_deterministic_across_publish_order():
    with obs_metrics.scoped() as a:
        a.counter("m.n_total", x="1").inc(1)
        a.gauge("m.g", y="2").set(5)
    with obs_metrics.scoped() as b:
        b.gauge("m.g", y="2").set(5)
        b.counter("m.n_total", x="1").inc(1)
    assert a.collect() == b.collect()
    assert a.collect()  # non-empty


# -- engine telemetry parity ------------------------------------------------

def _normalized_series(reg):
    series = reg.collect()
    for s in series:
        s["labels"].pop("engine", None)
    return series


def test_sim_engines_publish_identical_metric_series():
    """Node and vectorized runs of the same lossy fat-tree job emit the
    SAME metric series (names, labels, values) — telemetry parity."""
    with obs_metrics.scoped() as reg_n:
        _run_lossy_fat_tree("node")
    with obs_metrics.scoped() as reg_v:
        _run_lossy_fat_tree("vectorized")
    sn, sv = _normalized_series(reg_n), _normalized_series(reg_v)
    assert sn, "sim run published no metrics"
    assert sn == sv
    # loss actually exercised the transport series
    retx = [s for s in sn if s["name"] == "transport.retransmissions_total"]
    assert retx and sum(s["value"] for s in retx) > 0


def test_sim_publishes_expected_series_names():
    with obs_metrics.scoped() as reg:
        res = _run_small_job(tag="t0")
    names = {s["name"] for s in reg.collect()}
    for want in ("sim.job.jct_s", "sim.job.delivered_records_total",
                 "sim.level.records_in_total", "sim.level.evictions_total",
                 "sim.link.wire_bytes_total", "transport.timeouts_total"):
        assert want in names, f"missing series {want}"
    assert reg.value("sim.job.jct_s", job="t0", engine="node", agg="1",
                     op="sum") == res.jct_s


# -- publishers in the other layers -----------------------------------------

def test_dataplane_and_planner_publish():
    with obs_metrics.scoped() as reg:
        dataplane.simulate_plan(_small_plan(), data_amount=512,
                                key_variety=64, dist="zipf")
        ft = planner.FatTreeTopology(pods=4, tors_per_pod=2,
                                     hosts_per_tor=2, oversubscription=4.0,
                                     table_pairs=256)
        planner.place_aggregation_tree(ft, per_host_pairs=16,
                                       key_variety=64, policy="auto")
        names = {s["name"] for s in reg.collect()}
    for want in ("dataplane.level.records_in_total",
                 "dataplane.level.reduction",
                 "dataplane.level.predicted_reduction",
                 "dataplane.end_to_end_reduction",
                 "planner.placement.candidates_scored_total",
                 "planner.placement.scarce_uplink_bytes"):
        assert want in names, f"missing series {want}"


def test_instrumented_step_counts_calls_and_forwards_attrs():
    def step(x):
        return x + 1

    step.custom_marker = "here"
    with obs_metrics.scoped() as reg:
        wrapped = obs_metrics.instrument_step(step, name="train.step",
                                              labels={"mode": "t"})
        assert wrapped(1) == 2
        assert wrapped(2) == 3
        assert wrapped.custom_marker == "here"
        assert reg.value("train.step.calls_total", mode="t") == 2.0
        assert reg.value("train.step.wall_s", mode="t")["count"] == 2


# -- dashboard artifacts ----------------------------------------------------

def test_write_obs_artifacts_end_to_end(tmp_path):
    with obs_metrics.scoped() as reg, obs_trace.scoped_tracer() as tr:
        _run_small_job(tag="dash")
        dataplane.simulate_plan(_small_plan(), data_amount=512,
                                key_variety=64, dist="zipf")
        paths = obs_report.write_obs_artifacts(
            tmp_path, registry=reg, tracer=tr, title="test dashboard")
    assert set(paths) == {"metrics", "trace", "dashboard_md",
                          "dashboard_html"}
    with open(paths["trace"]) as f:
        trace = json.load(f)
    assert trace["traceEvents"]
    with open(paths["metrics"]) as f:
        metrics = json.load(f)
    assert metrics["metrics"]
    html = open(paths["dashboard_html"]).read()
    md = open(paths["dashboard_md"]).read()
    for doc in (html, md):
        assert "JCT" in doc
        assert "reduction" in doc.lower()
    assert "test dashboard" in html
    # the Eq.3 join made it in: predicted vs simulated per level
    assert "predicted" in md.lower()


def test_dashboard_renders_without_trace(tmp_path):
    with obs_metrics.scoped() as reg:
        _run_small_job(tag="mtr")
        md = obs_report.dashboard_markdown(reg.collect(), None)
    assert "mtr" in md
