"""Packet-level JCT simulator: acceptance + integration (DESIGN.md §7).

Pins the PR's acceptance criteria: on the paper's 8-mapper Zipf word-count
the simulator reports >= 40% JCT reduction vs the host-only baseline, and
at loss = 0 the delivered record/byte counts match ``run_cascade`` exactly
for every registered AggOp.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops, dataplane, kvagg, planner
from repro.core import reduction_model as rm
from repro.net import sim as netsim
from repro.net import simulate, wire
from repro.runtime.fault_tolerance import StragglerInjector, StragglerMonitor

EMPTY = int(kvagg.EMPTY_KEY)


def _plan(caps, op="sum"):
    return dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=c) for c in caps))


def _sim(keys, vals, **kw):
    return simulate(netsim.JobSpec(keys=keys, values=vals, **kw))


def test_wordcount_jct_reduction_at_least_40pct():
    """The paper's 8-mapper Zipf word-count (Fig. 10): in-network
    aggregation cuts the measured JCT by >= 40%."""
    n_workers, per_worker, variety = 8, 1024, 1024
    keys = rm.zipf_keys(n_workers * per_worker, variety, skew=0.99, seed=0)
    vals = np.ones_like(keys, dtype=np.float32)
    cfg = netsim.NetConfig(link_gbps=(netsim.TEN_GBE, netsim.TEN_GBE),
                           reducer_gbps=netsim.TEN_GBE)
    jct = netsim.jct_comparison(keys, vals, fanins=(4, 2),
                                plan=_plan([512, 512]), cfg=cfg)
    assert jct["jct_host_only_s"] > 0
    assert jct["jct_saved"] >= 0.40, jct
    # and the aggregated result is still the exact word count
    sw = jct["switchagg"]
    assert sw["delivered_records"] == len(set(keys.tolist()))
    # host-only pushes every mapper record over the reducer in-link
    assert jct["host_only"]["arrived_records"] == n_workers * per_worker


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_lossless_delivery_matches_run_cascade(op):
    """loss=0: delivered record/byte counts match run_cascade exactly, and
    delivered values match the exact cascade result, for every AggOp."""
    n, variety = 600, 64
    keys = rm.zipf_keys(n, variety, seed=2).astype(np.int32)
    vals = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    plan = _plan([32, 16], op=op)
    cfg = netsim.NetConfig(records_per_packet=32)
    res = _sim(keys, vals, fanins=(2, 2), plan=plan, cfg=cfg)
    ref = dataplane.run_cascade(jnp.asarray(keys), jnp.asarray(vals), plan)
    ref_keys = np.asarray(ref.keys)
    ref_vals = np.asarray(ref.values)
    n_unique = int(np.sum(ref_keys != EMPTY))
    # exact record/byte count match
    assert res.delivered_records == n_unique
    assert res.delivered_bytes == wire.stream_wire_bytes(
        n_unique, cfg.records_per_packet)
    # exact key set, matching finalized values
    want = {int(k): v for k, v in zip(ref_keys, ref_vals) if k != EMPTY}
    got = dict(zip(res.delivered_keys.tolist(), res.delivered_values))
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5,
                                   err_msg=f"op={op} key={k}")
    assert res.retransmissions == 0 and res.packets_dropped == 0


def test_host_only_baseline_forwards_everything():
    keys = rm.uniform_keys(512, 32, seed=1).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    res = _sim(keys, vals, fanins=(4, 2), op="sum", aggregate=False,
               cfg=netsim.NetConfig(records_per_packet=32))
    assert res.arrived_records == 512
    assert res.per_level[0]["records_in"] == 512
    assert res.per_level[-1]["records_out"] == 512
    # the reducer's host merge still produces the exact table
    assert res.delivered_table() == dict_aggregate(keys, vals, "sum")


def test_host_only_baseline_honors_plan_op():
    """The plan's op governs the host-only run too: a mean comparison must
    not fall back to sum on the baseline side."""
    keys = rm.uniform_keys(256, 16, seed=8).astype(np.int32)
    vals = np.random.default_rng(2).standard_normal(256).astype(np.float32)
    jct = netsim.jct_comparison(
        keys, vals, fanins=(2, 2), plan=_plan([16, 16], op="mean"),
        cfg=netsim.NetConfig(records_per_packet=32))
    host = _sim(
        keys, vals, fanins=(2, 2), plan=_plan([16, 16], op="mean"),
        aggregate=False, cfg=netsim.NetConfig(records_per_packet=32))
    want = dict_aggregate(keys, vals, "mean")
    assert host.op == "mean"
    got = host.delivered_table()
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)
    assert jct["host_only"]["op"] == "mean"


def test_run_cascade_stream_empty_stream_lane_ops():
    """An empty (or all-padding) stream still finalizes lane-carrying ops."""
    for op in ("mean", "logsumexp", "sum"):
        res = dataplane.run_cascade_stream([], _plan([8, 8], op=op))
        assert int(res.n_in) == 0 and int(res.n_out) == 0
        assert np.asarray(res.keys).shape == (0,)
        assert np.asarray(res.values).shape == (0,)
        pad = (np.full((5,), EMPTY, np.int32), np.zeros((5,), np.float32))
        res = dataplane.run_cascade_stream([pad], _plan([8], op=op),
                                           batch_pad=5)
        assert int(res.n_in) == 0
        assert np.asarray(res.values).shape == (0,)


def test_more_loss_never_cheaper_and_still_exact():
    keys = rm.zipf_keys(1024, 128, seed=3).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    cfg0 = netsim.NetConfig(records_per_packet=32)
    base = _sim(keys, vals, fanins=(4,), plan=_plan([64]), cfg=cfg0)
    lossy = _sim(
        keys, vals, fanins=(4,), plan=_plan([64]),
        cfg=dataclasses.replace(cfg0, loss_rate=0.05, seed=5))
    assert lossy.retransmissions > 0
    assert lossy.jct_s > base.jct_s
    assert lossy.delivered_table() == base.delivered_table()
    # retransmitted wire bytes must show up in the drain calibration:
    # payload is credited once per PSN, so the lossy factor is strictly
    # larger than the lossless one on every axis that saw a retransmit
    base_f = netsim.drain_calibration(base)
    lossy_f = netsim.drain_calibration(lossy)
    assert all(lossy_f[ax] >= base_f[ax] for ax in base_f)
    assert any(lossy_f[ax] > base_f[ax] for ax in base_f)


def test_straggler_delay_inflates_jct_tail():
    """runtime.fault_tolerance's injector drives the simulator clock: one
    slow mapper shows up as JCT tail inflation and trips the monitor."""
    keys = rm.zipf_keys(2048, 256, seed=4).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    cfg = netsim.NetConfig(records_per_packet=32)
    common = dict(fanins=(4, 2), plan=_plan([128, 128]), cfg=cfg)
    base = _sim(keys, vals, **common)
    delay = 50 * base.jct_s  # a mapper 50x slower than the whole lossless job
    inject = StragglerInjector({3: delay})
    slow = _sim(keys, vals, mapper_delay=inject, **common)
    assert slow.jct_s >= base.jct_s + 0.9 * delay  # the tail IS the straggler
    assert slow.mapper_finish_s[3] == max(slow.mapper_finish_s)
    # the per-mapper finish times trip the online straggler monitor
    monitor = StragglerMonitor(factor=3.0, warmup=2)
    for m, t in enumerate(slow.mapper_finish_s):
        monitor.observe(m, t)
    assert [step for step, _, _ in monitor.events] == [3]


def test_scheduler_plan_roundtrip_and_drain_calibration():
    """The simulator consumes a JobScheduler plan and its measured drain
    factors feed back into the scheduler's congestion scoring."""
    topo = planner.Topology(links=(
        planner.LinkBudget(axis="data", fanin=4, gbps=netsim.TEN_GBE),
        planner.LinkBudget(axis="pod", fanin=2, gbps=netsim.TEN_GBE / 4),
    ))
    sched = planner.JobScheduler(topo, combiner_budget_pairs=256)
    jp = sched.admit(planner.LaunchRequest(
        job_id=1, n_workers=8, expected_pairs=256, key_variety=64,
        grad_bytes=1 << 20))
    keys = rm.zipf_keys(8 * 256, 64, seed=5).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    res = simulate(jp, keys, vals)
    # the sim ran the scheduler's tree: axes + link stats line up
    assert set(res.axes) == {"data", "pod"}
    assert set(res.link_stats) == {"data", "pod", "reducer"}
    factors = netsim.drain_calibration(res)
    assert set(factors) == {"data", "pod"}
    # headers (and any retransmits) make the wire strictly slower than the
    # payload-only model
    assert all(f > 1.0 for f in factors.values())
    before = sched.report().max_drain_s
    sched.calibrate(factors)
    after = sched.report().max_drain_s
    assert after > before
    with pytest.raises(ValueError):
        sched.calibrate({"data": 0.0})


def test_run_cascade_stream_counts_and_jit_padding():
    """The dataplane's packet-batched ingest: telemetry counts real records
    only, and padded batches do not perturb the result."""
    keys = rm.uniform_keys(300, 40, seed=6).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    plan = _plan([32, 0])  # bounded leaf, exact root
    batches = [(keys[i:i + 48], vals[i:i + 48]) for i in range(0, 300, 48)]
    res = dataplane.run_cascade_stream(batches, plan, batch_pad=48)
    assert int(res.n_in) == 300
    got = {int(k): float(v) for k, v in
           zip(np.asarray(res.keys), np.asarray(res.values)) if k != EMPTY}
    assert got == dict_aggregate(keys, vals, "sum")
    # exact root holds everything until flush: its n_out is the key variety
    assert int(res.level_out[-1]) == len(got)
