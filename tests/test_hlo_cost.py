"""Validate the trip-count-aware HLO walker against known-cost programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _walk(fn, *args):
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_cost.analyze(hlo), hlo


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    got, _ = _walk(lambda x, y: x @ y, a, b)
    want = 2 * 256 * 512 * 128
    assert got["flops"] == pytest.approx(want, rel=0.05), got["flops"] / want


def test_scan_multiplies_by_trip_count():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128,), jnp.float32)

    def fn(w, x):
        def body(c, _):
            return jnp.tanh(w @ c), None

        out, _ = jax.lax.scan(body, x, None, length=17)
        return out

    got, hlo = _walk(fn, w, x)
    want = 17 * 2 * 128 * 128
    assert got["flops"] == pytest.approx(want, rel=0.15), got["flops"] / want


def test_grad_of_scan_matmul():
    """fwd+bwd of scanned matmul: 3x fwd flops (fwd + 2 bwd matmuls)."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

    def loss(w, x):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=9)
        return jnp.sum(out * out)

    got, hlo = _walk(lambda w, x: jax.grad(loss)(w, x), w, x)
    fwd = 9 * 2 * 32 * 64 * 64
    want = 3 * fwd
    assert got["flops"] == pytest.approx(want, rel=0.35), got["flops"] / want


def test_remat_scan_flops_counts_recompute():
    """jax.checkpoint body: fwd + recompute + bwd = ~4x fwd units."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

    def loss(w, x):
        @jax.checkpoint
        def body(c, _):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, None, length=9)
        return jnp.sum(out * out)

    got, _ = _walk(lambda w, x: jax.grad(loss)(w, x), w, x)
    fwd = 9 * 2 * 32 * 64 * 64
    # fwd + recompute-fwd + dgrad + wgrad = 4 matmul units
    want = 4 * fwd
    assert got["flops"] == pytest.approx(want, rel=0.4), got["flops"] / want


def test_tpu_bytes_projection_matmul_chain():
    """Elementwise chains fuse on TPU: projected bytes ~= anchor traffic."""
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def fn(x):
        y = x @ x
        y = jnp.tanh(y) * 2.0 + 1.0  # fuses into the matmul epilogue on TPU
        return y

    got, _ = _walk(fn, a)
    anchor = 3 * 512 * 512 * 4  # read x twice + write y
    # allow 2x slop for CPU-HLO structure, but NOT the 5x of per-op counting
    assert got["bytes"] <= 3 * anchor, (got["bytes"], anchor)
    assert got["bytes"] >= anchor * 0.5
