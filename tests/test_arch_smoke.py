"""Per-architecture smoke tests on reduced configs (CPU, 1 device).

Two layers of checking per assigned arch:
  1. train-step smoke: one real optimizer step; finite loss, params move.
  2. decode consistency: prefill + step-by-step decode reproduces the dense
     forward's logits at every decoded position (validates KV/SSM caches,
     ring buffers, RoPE offsets, MLA latents, hybrid interleave).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.reduced import reduced_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.layers import lm_logits, rms_norm
from repro.models.model import LMModel
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, make_lr_schedule
from repro.train.step import TrainProfile, build_train_step

ARCHS = list(configs.ARCH_IDS)


def _cfg(arch):
    cfg = reduced_config(arch)
    over = {"dtype": "float32"}
    if cfg.moe is not None:  # no token drops -> decode matches dense exactly
        over["moe"] = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, **over)


def _batch(cfg, b=2, s=16, seed=0):
    data = SyntheticLMData(cfg, DataConfig(seq_len=s, global_batch=b, seed=seed))
    return data.batch_at(0)


def _dense_logits(model, params, batch):
    """All-position logits of the dense forward (ground truth)."""
    cfg = model.cfg
    x = model._embed_inputs(params, batch)
    opt = dataclasses.replace(model.opt, prefix_len=cfg.prefix_tokens, remat="none")
    x, _, _ = tfm.run_stack_dense(x, params, cfg, model.policy, opt)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params.get("head", params["embed"])
    return lm_logits(x, table, cfg.logit_softcap, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = _cfg(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # chunks of 8 divide both 16 (text) and 16+8 (vision-prefixed) sequences
    prof = TrainProfile(q_chunk=8, k_chunk=8, moe_token_chunk=32, remat="none")
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt_cfg = AdamWConfig()
    step_fn, shardings, _ = build_train_step(
        cfg, mesh, prof, opt_cfg, make_lr_schedule(1e-3, 2, 10),
        batch_example=batch, params_example=params,
    )
    opt_state = adamw_init(params, opt_cfg)
    p0 = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    new_params, new_opt, metrics = step_fn(params, opt_state, batch,
                                           jnp.zeros((), jnp.int32))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params moved
    moved = jax.tree.map(
        lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))),
        p0, jax.tree.map(np.asarray, new_params))
    assert max(jax.tree.leaves(moved)) > 0
    # a second step with the same shapes reuses the compiled fn and stays finite
    _, _, m2 = step_fn(new_params, new_opt, _batch(cfg, seed=1),
                       jnp.ones((), jnp.int32))
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency(arch):
    """prefill + decode == dense forward, position by position."""
    cfg = _cfg(arch)
    model = LMModel(cfg, opt=tfm.ApplyOptions(q_chunk=8, k_chunk=8,
                                              moe_token_chunk=64, remat="none"))
    params = model.init(jax.random.PRNGKey(1))
    b, total, n_pre = 2, 16, 8
    batch = _batch(cfg, b=b, s=total, seed=2)
    want = np.asarray(_dense_logits(model, params, batch))  # [B, S(+pre), V]

    audio = cfg.frontend == "audio_stub"
    vision = cfg.frontend == "vision_stub"
    pre_batch = dict(batch)
    if audio:
        pre_batch = {"frame_embeds": batch["frame_embeds"][:, :n_pre]}
    else:
        pre_batch["tokens"] = batch["tokens"][:, :n_pre]
        pre_batch.pop("labels", None)
    cache_len = total + cfg.prefix_tokens

    logits, caches = jax.jit(
        lambda p, bb: model.prefill(p, bb, cache_len)
    )(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits)[:, 0],
        want[:, cfg.prefix_tokens + n_pre - 1],
        atol=2e-3, rtol=1e-3,
    )

    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for k in range(n_pre, total):
        if audio:
            tok = batch["frame_embeds"][:, k:k + 1]
        else:
            tok = batch["tokens"][:, k:k + 1]
        cur = jnp.asarray(cfg.prefix_tokens + k, jnp.int32)
        logits, caches = step(params, tok, caches, cur)
        np.testing.assert_allclose(
            np.asarray(logits)[:, 0], want[:, cfg.prefix_tokens + k],
            atol=2e-3, rtol=1e-3,
            err_msg=f"{arch}: decode mismatch at position {k}",
        )


def test_decode_ring_buffer_wraps():
    """gemma2 local layers: decoding past the window wraps the ring buffer."""
    cfg = dataclasses.replace(_cfg("gemma2-27b"), window=8)
    model = LMModel(cfg, opt=tfm.ApplyOptions(q_chunk=8, k_chunk=8, remat="none"))
    params = model.init(jax.random.PRNGKey(3))
    b, total, n_pre = 1, 24, 8
    batch = _batch(cfg, b=b, s=total, seed=3)
    want = np.asarray(_dense_logits(model, params, batch))
    pre = {"tokens": batch["tokens"][:, :n_pre]}
    logits, caches = model.prefill(params, pre, total)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for k in range(n_pre, total):  # wraps at k = 8 + window
        tok = batch["tokens"][:, k:k + 1]
        logits, caches = step(params, tok, caches, jnp.asarray(k, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits)[:, 0], want[:, k], atol=2e-3, rtol=1e-3,
            err_msg=f"ring-buffer mismatch at pos {k}",
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_published_size(arch):
    """Config fidelity: param_count() lands near the architecture's name."""
    published = {
        "gemma2-27b": 27e9, "phi4-mini-3.8b": 3.8e9, "gemma3-4b": 4e9,
        "qwen3-32b": 32e9, "jamba-1.5-large-398b": 398e9,
        "deepseek-v2-236b": 236e9, "olmoe-1b-7b": 7e9, "paligemma-3b": 3e9,
        "mamba2-780m": 780e6, "musicgen-medium": 1.5e9,
    }
    cfg = configs.get_config(arch)
    n = cfg.param_count()
    lo, hi = 0.72 * published[arch], 1.35 * published[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params vs published {published[arch]/1e9:.1f}B"


def test_active_params_less_than_total_for_moe():
    for arch in ("deepseek-v2-236b", "olmoe-1b-7b", "jamba-1.5-large-398b"):
        cfg = configs.get_config(arch)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()
    # deepseek-v2: 21B active of 236B (paper)
    ds = configs.get_config("deepseek-v2-236b")
    assert 14e9 <= ds.active_param_count() <= 30e9
